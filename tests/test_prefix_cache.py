"""Cross-request prefix caching (repro/serving/prefix_cache.py): radix
trie invariants driven by a shadow dict-of-prefixes model (property-based
where hypothesis is available, seeded otherwise), scheduler integration
(marginal admission, parking at retire, LRU eviction), the golden
trace fixture (tests/fixtures/prefix_trace/), and the determinism
contract — prefix-cached serving is token-for-token the no-cache paged
path, including under kv8 int8 pools and forced-host TP=2."""

import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.serving import PagePool, PrefixCache, Request, Scheduler

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "prefix_trace")


# ---------------------------------------------------------------------------
# Trie unit tests: insert / match / evict round-trips
# ---------------------------------------------------------------------------

def _park(pool, cache, tokens, rid=None):
    """Simulate a retiring request ceding freshly-prefilled pages for
    ``tokens`` (must be page-aligned) to the cache."""
    pages = pool.alloc(len(tokens) // pool.page_size)
    assert pages is not None
    return cache.insert(tokens, pages, rid=rid)


def test_insert_match_roundtrip():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = list(range(10, 22))                     # 3 full pages
    parked, deduped = _park(pool, cache, toks)
    assert (parked, deduped) == (3, 0)
    pages, n = cache.match(toks)
    assert n == 12 and len(pages) == 3
    # partial match: only full pages of the query's prefix count
    pages, n = cache.match(toks[:7])
    assert n == 4 and len(pages) == 1
    # the limit caps matching (admission passes prompt_len - 1)
    pages, n = cache.match(toks, limit=11)
    assert n == 8 and len(pages) == 2
    # diverging tokens stop the walk at the shared prefix
    pages, n = cache.match(toks[:4] + [99, 99, 99, 99])
    assert n == 4
    assert cache.match([1, 2, 3, 4]) == ([], 0)
    cache.check_invariants()


def test_insert_dedupes_duplicate_prefill():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = list(range(8))
    _park(pool, cache, toks)
    free_before = pool.num_free
    parked, deduped = _park(pool, cache, toks)     # same path again
    assert (parked, deduped) == (0, 2)
    assert pool.num_free == free_before            # duplicate pages freed
    # a diverging suffix grafts onto the canonical shared prefix
    parked, deduped = _park(pool, cache, toks[:4] + [50, 51, 52, 53])
    assert (parked, deduped) == (1, 1)
    assert len(cache.prefixes()) == 3
    cache.check_invariants()


def test_insert_rejects_ragged_tokens():
    pool = PagePool(8, 4)
    cache = PrefixCache(pool)
    pages = pool.alloc(1)
    with pytest.raises(ValueError, match="insert"):
        cache.insert([1, 2, 3], pages)
    pool.free(pages)


def test_evict_lru_leaves_first():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    _park(pool, cache, [1, 2, 3, 4, 5, 6, 7, 8])   # chain a: 2 pages
    _park(pool, cache, [9, 10, 11, 12])            # chain b: 1 page
    cache.match([1, 2, 3, 4, 5, 6, 7, 8])          # touch a -> b is LRU
    assert cache.evict(1) == 1
    assert ([], 0) == cache.match([9, 10, 11, 12])     # b evicted
    assert cache.match([1, 2, 3, 4, 5, 6, 7, 8])[1] == 8
    # evicting 2 more consumes chain a leaf-first (parent becomes leaf)
    assert cache.evict(2) == 2
    assert cache.num_pages == 0
    assert pool.num_allocated == 0
    cache.check_invariants()


def test_evict_skips_pages_shared_with_live_requests():
    pool = PagePool(16, 4)
    cache = PrefixCache(pool)
    toks = list(range(8))
    _park(pool, cache, toks)
    pages, n = cache.match(toks)
    pool.share(pages)                              # live request co-owns
    assert cache.evict(10) == 0                    # nothing evictable
    assert cache.match(toks)[1] == 8
    pool.free(pages)                               # request retires
    assert cache.evict(10) == 2
    assert pool.num_allocated == 0
    cache.check_invariants()


# ---------------------------------------------------------------------------
# Shadow dict-of-prefixes model: random insert/match/evict traces
# ---------------------------------------------------------------------------

def drive_shadow_trace(ops, num_pages=24, page_size=4):
    """Interpret (op, a, b) steps against a PrefixCache and an
    independent shadow model, asserting agreement after EVERY op:

      ("park", seed, n_pages)  — a retiring request cedes pages for a
                                 random token seq (biased to share
                                 prefixes via a small token alphabet)
      ("acquire", seed, _)     — match + share (a live request pins)
      ("release", i, _)        — free acquired handle i (request ends)
      ("evict", n, _)          — reclaim up to n pages

    Shadow state: prefix-tuple -> page dict (insert/match agreement),
    plus live handles (refcount agreement). Eviction is checked
    structurally: only refcount-1 leaves leave the trie, exactly as many
    as reported, never pinned pages."""
    pool = PagePool(num_pages, page_size)
    cache = PrefixCache(pool)
    shadow = {}                    # prefix tuple -> page
    handles = []                   # live acquired page lists

    def tokens_for(seed, n_tokens):
        rng = np.random.default_rng(seed)
        return [int(t) for t in rng.integers(0, 3, n_tokens)]

    def check():
        cache.check_invariants()
        assert cache.prefixes() == shadow
        # refcount model: cache ownership + one per live handle
        want = {}
        for p in shadow.values():
            want[p] = want.get(p, 0) + 1
        for h in handles:
            for p in h:
                want[p] = want.get(p, 0) + 1
        for p in range(1, num_pages):
            assert pool.refcount(p) == want.get(p, 0), \
                f"page {p}: pool {pool.refcount(p)} != shadow {want.get(p, 0)}"

    for op, a, b in ops:
        if op == "park":
            n = 1 + b % 3
            toks = tokens_for(a, n * page_size)
            pages = pool.alloc(n)
            if pages is None:
                continue           # pool full: a real scheduler would evict
            cache.insert(toks, pages)
            node = ()
            for i, page in zip(range(0, n * page_size, page_size), pages):
                node = node + tuple(toks[i:i + page_size])
                if node not in shadow:
                    shadow[node] = page
        elif op == "acquire":
            toks = tokens_for(a, 3 * page_size)
            pages, n = cache.match(toks)
            # shadow agreement on the match result itself
            want = []
            node = ()
            for i in range(0, len(toks), page_size):
                node = node + tuple(toks[i:i + page_size])
                if node not in shadow:
                    break
                want.append(shadow[node])
            assert pages == want and n == len(want) * page_size
            if pages:
                pool.share(pages)
                handles.append(list(pages))
        elif op == "release" and handles:
            pool.free(handles.pop(a % len(handles)))
        elif op == "evict":
            before = dict(shadow)
            pinned = {p for h in handles for p in h}
            freed = cache.evict(a % 4)
            now = cache.prefixes()
            removed = {k: v for k, v in before.items() if k not in now}
            assert len(removed) == freed
            assert now == {k: v for k, v in before.items() if k in now}
            for k, page in removed.items():
                assert page not in pinned, "evicted a pinned page"
                # leaves-first: nothing remaining extends an evicted path
                assert not any(n2[:len(k)] == k for n2 in now)
            shadow = now
        check()
    return pool, cache, shadow, handles


def _drain_shadow(pool, cache, shadow, handles):
    while handles:
        pool.free(handles.pop())
    assert cache.evict(len(shadow)) == len(shadow)
    assert cache.prefixes() == {}
    pool.check_invariants()
    assert pool.num_allocated == 0
    assert pool.num_free == pool.num_pages - 1


def test_shadow_trace_seeded():
    rng = np.random.default_rng(11)
    names = ("park", "acquire", "release", "evict")
    for _ in range(25):
        ops = [(names[int(rng.integers(0, 4))], int(rng.integers(0, 8)),
                int(rng.integers(0, 8)))
               for _ in range(int(rng.integers(1, 40)))]
        pool, cache, shadow, handles = drive_shadow_trace(
            ops, num_pages=int(rng.integers(6, 28)))
        _drain_shadow(pool, cache, shadow, handles)


@given(st.lists(st.tuples(st.sampled_from(["park", "acquire", "release",
                                           "evict"]),
                          st.integers(0, 8), st.integers(0, 8)),
                min_size=1, max_size=50),
       st.integers(6, 28))
@settings(max_examples=50, deadline=None)
def test_property_shadow_trace_agreement(ops, num_pages):
    """Every interleaving of parks, pinned acquires, releases, and
    evictions keeps the trie in exact agreement with the shadow
    dict-of-prefixes and the pool leak-free (checked after every op)."""
    pool, cache, shadow, handles = drive_shadow_trace(
        ops, num_pages=num_pages)
    _drain_shadow(pool, cache, shadow, handles)


# ---------------------------------------------------------------------------
# Scheduler integration: host-only trace driver with a prefix cache
# ---------------------------------------------------------------------------

def drive_cached_trace(sched, *, log=None, step0=0):
    """Drain a scheduler (prefix cache attached) without a model; fake
    generation appends deterministic per-request token ids. Optionally
    collects the cache's event log stamped with step indices."""
    cache = sched.prefix_cache
    guard, step = 0, step0
    while sched.has_work():
        guard += 1
        assert guard < 10_000, "trace did not drain"
        n_ev = len(cache.events) if cache is not None else 0
        sched.retire_finished()
        sched.admit()
        chunk = sched.next_prefill()
        if chunk is not None:
            b, tokens, start, valid = chunk
            sched.mark_prefilled(b, valid)
            seq = sched.slots[b]
            if seq.prompt_done:
                seq.req.tokens.append(seq.req.rid % 5 + 1)
        mask = sched.decode_mask()
        for b in np.nonzero(mask)[0]:
            seq = sched.slots[int(b)]
            seq.req.tokens.append(seq.req.rid % 5 + 1)
        sched.advance_decoded(mask)
        sched.check_invariants()
        if log is not None:
            for ev in cache.events[n_ev:]:
                log.append({"step": step, **ev})
        step += 1
    # The final retire always happens inside the loop: a finished seq
    # keeps its slot (has_work() true) until the next iteration parks it.
    sched.check_invariants()
    return step


def _cached_sched(num_pages=32, page_size=4, max_batch=2, chunk=4,
                  record=False):
    pool = PagePool(num_pages, page_size)
    cache = PrefixCache(pool, record_events=record)
    sched = Scheduler(pool, max_batch=max_batch,
                      max_pages=pool.pages_for(64), prefill_chunk=chunk,
                      prefix_cache=cache)
    return pool, cache, sched


def test_retired_prefix_hittable_by_next_request():
    """Regression for the retire path: a retired request's prefix must be
    parked (not freed) and hittable by the very next request."""
    pool, cache, sched = _cached_sched()
    prompt = np.arange(100, 112, dtype=np.int32)       # 3 full pages
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    drive_cached_trace(sched)
    assert cache.num_pages > 0, "retire freed pages instead of parking"
    assert pool.num_allocated == cache.num_pages
    sched.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=2))
    drive_cached_trace(sched)
    s = cache.stats()
    assert s["hits"] == 1 and s["hit_tokens"] >= 8, s
    assert sched.total_cached_tokens == s["hit_tokens"]


def test_retire_parks_generated_tokens_too():
    """The parked path covers prompt + generated tokens (all resident
    tokens), so a follow-up whose prompt extends the full conversation
    hits past the original prompt."""
    pool, cache, sched = _cached_sched(page_size=4)
    prompt = np.arange(50, 58, dtype=np.int32)          # 8 tokens
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    drive_cached_trace(sched)
    # resident = 8 prompt + 4 generated (last token never written) = 3 pages
    assert cache.num_pages == 3
    gen = [0 % 5 + 1] * 4
    follow = np.concatenate([prompt, np.asarray(gen, np.int32),
                             np.arange(90, 94, dtype=np.int32)])
    pages, n = cache.match(follow)
    assert n == 12, "generated tokens not hittable"


def test_fully_cached_prompt_still_prefills_last_token():
    """A prompt whose every page is cached is capped at prompt_len - 1:
    the last token must prefill to produce the first-token logits."""
    pool, cache, sched = _cached_sched(page_size=4, chunk=4)
    prompt = np.arange(10, 18, dtype=np.int32)          # exactly 2 pages
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    drive_cached_trace(sched)
    p0 = sched.total_prefill_tokens
    sched.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=3))
    drive_cached_trace(sched)
    # only 1 of 2 pages may be reused; the 4-token tail chunk prefills
    assert sched.total_cached_tokens == 4
    assert sched.total_prefill_tokens - p0 == 4


def test_eviction_under_pressure_makes_admission_succeed():
    """A pool-sized request admits only after LRU eviction reclaims
    refcount-1 parked pages."""
    pool, cache, sched = _cached_sched(num_pages=9, page_size=4,
                                       max_batch=1)
    sched.submit(Request(rid=0, prompt=np.arange(1, 17, dtype=np.int32),
                         max_new_tokens=2))
    drive_cached_trace(sched)
    parked = cache.num_pages
    assert parked >= 4                                  # pool mostly parked
    # A disjoint-prefix request needs more pages than are free: admission
    # must evict parked pages rather than deadlock.
    sched.submit(Request(rid=1,
                         prompt=np.arange(60, 76, dtype=np.int32),
                         max_new_tokens=2))
    drive_cached_trace(sched)
    assert len(sched.finished) == 2
    assert cache.stats()["evicted_pages"] > 0
    sched.check_invariants()


def test_marginal_page_accounting_on_hit():
    """Admission of a hitting request allocates ONLY the marginal pages:
    the free-list drop equals total-need minus cached pages."""
    pool, cache, sched = _cached_sched(num_pages=32, page_size=4,
                                       max_batch=1, chunk=4)
    prompt = np.arange(100, 112, dtype=np.int32)        # 12 tokens
    sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    drive_cached_trace(sched)
    free_before = pool.num_free
    req = Request(rid=1, prompt=prompt.copy(), max_new_tokens=5)
    sched.submit(req)
    sched.admit()
    seq = sched.slots[0]
    assert seq is not None and seq.cached_tokens == 8   # 2 full pages
    # Optimistic admission reserves the chunk-padded prefill view only
    # (decode grows pages on demand; worst-case is never pre-charged).
    total_need = pool.pages_for(-(-req.prompt_len // 4) * 4)
    assert free_before - pool.num_free == total_need - 2
    assert seq.pages[:2] == cache.match(prompt, limit=8)[0]
    drive_cached_trace(sched)


# ---------------------------------------------------------------------------
# Golden fixture: byte-for-byte pinned cache-hit/evict log
# ---------------------------------------------------------------------------

def _golden_log():
    """Drive the committed trace deterministically and serialize the
    per-step cache event log."""
    with open(os.path.join(FIXTURES, "trace.json")) as f:
        spec = json.load(f)
    pool, cache, sched = _cached_sched(
        num_pages=spec["num_pages"], page_size=spec["page_size"],
        max_batch=spec["max_batch"], chunk=spec["prefill_chunk"],
        record=True)
    log, step = [], 0
    for batch in spec["batches"]:
        for r in batch:
            sched.submit(Request(
                rid=r["rid"], prompt=np.asarray(r["prompt"], np.int32),
                max_new_tokens=r["gen"]))
        step = drive_cached_trace(sched, log=log, step0=step)
    log.append({"op": "final_stats", **cache.stats()})
    return log


def test_golden_prefix_trace_log():
    """The shared-prefix request trace under tests/fixtures/prefix_trace/
    must reproduce its committed per-step hit/insert/evict log exactly
    (same pages, same steps, same stats) — any drift in admission order,
    LRU policy, or dedupe behavior shows up as a diff here."""
    got = _golden_log()
    with open(os.path.join(FIXTURES, "expected_log.json")) as f:
        want = json.load(f)
    assert got == want, (
        "prefix-trace event log drifted from the golden fixture;\n"
        "if the change is intentional, regenerate with:\n"
        "  PYTHONPATH=src:tests python -c 'import json, test_prefix_cache"
        " as t; print(json.dumps(t._golden_log(), indent=1))'"
        f"\ngot:\n{json.dumps(got, indent=1)}")


# ---------------------------------------------------------------------------
# End-to-end determinism: cached == no-cache paged, incl. kv8 and TP=2
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="pfx-t", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=128, dtype="float32")


def _shared_prefix_reqs(rng, vocab, n=6, sys_len=12):
    sysp = rng.integers(1, vocab, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        sfx = rng.integers(1, vocab,
                           int(rng.integers(1, 6))).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([sysp, sfx]),
                            max_new_tokens=int(rng.integers(1, 5))))
    return reqs


def _run_engines(quant=None):
    import copy

    import jax

    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    reqs = _shared_prefix_reqs(np.random.default_rng(3), cfg.vocab_size)
    kw = dict(num_pages=40, page_size=4, max_batch=3, max_seq_len=32,
              prefill_chunk=4, quant=quant)
    base = ServingEngine(cfg, params, **kw)
    base.run(copy.deepcopy(reqs))
    cached = ServingEngine(cfg, params, prefix_cache=True, **kw)
    cached.run(copy.deepcopy(reqs))
    return base, cached


@pytest.mark.parametrize("quant", [None, "kv8"])
def test_trace_replay_cached_equals_nocache(quant):
    """Seeded multi-request shared-prefix trace: the prefix-cached engine
    generates token-for-token what the no-cache paged engine generates
    (float32 pools and kv8 int8 pools), avoids real prefill work, and
    leaks nothing beyond the parked pages."""
    base, cached = _run_engines(quant=quant)
    want = {r.rid: r.tokens for r in base.scheduler.finished}
    got = {r.rid: r.tokens for r in cached.scheduler.finished}
    assert got == want
    s = cached.prefix_cache.stats()
    assert s["hit_tokens"] > 0 and s["hits"] > 0, s
    assert cached.scheduler.total_prefill_tokens \
        < base.scheduler.total_prefill_tokens
    cached.scheduler.check_invariants()
    assert cached.pool.num_allocated == cached.prefix_cache.num_pages
    assert base.pool.num_allocated == 0


def test_trace_replay_tp2_cached_equals_single_device():
    """TP=2 over forced host devices: the prefix-cached sharded engine
    matches the single-device no-cache engine token-for-token (the pool
    and trie are host-side and shard-oblivious; kv pages are
    head-sharded)."""
    from conftest import run_in_subprocess
    out = run_in_subprocess("""
import copy, os, tempfile
os.environ["REPRO_TUNING_CACHE"] = tempfile.mkdtemp()
import jax, numpy as np
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.param import init_params
from repro.serving import Request, ServingEngine

cfg = ModelConfig(name="pfx-tp", family="dense", n_layers=2, d_model=32,
                  n_heads=8, n_kv_heads=4, head_dim=8, d_ff=64,
                  vocab_size=128, dtype="float32")
params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
rng = np.random.default_rng(3)
sysp = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
reqs = []
for i in range(5):
    sfx = rng.integers(1, cfg.vocab_size,
                       int(rng.integers(1, 6))).astype(np.int32)
    reqs.append(Request(rid=i, prompt=np.concatenate([sysp, sfx]),
                        max_new_tokens=int(rng.integers(1, 5))))
kw = dict(num_pages=40, page_size=4, max_batch=3, max_seq_len=32,
          prefill_chunk=4)
e1 = ServingEngine(cfg, params, **kw)
e1.run(copy.deepcopy(reqs))
want = {r.rid: r.tokens for r in e1.scheduler.finished}
e2 = ServingEngine(cfg, params, tp=2, prefix_cache=True, **kw)
e2.run(copy.deepcopy(reqs))
got = {r.rid: r.tokens for r in e2.scheduler.finished}
assert got == want, (got, want)
s = e2.prefix_cache.stats()
assert s["hit_tokens"] > 0, s
e2.scheduler.check_invariants()
assert e2.pool.num_allocated == e2.prefix_cache.num_pages
print("OK", s["hit_tokens"])
""", devices=2, timeout=900)
    assert "OK" in out
