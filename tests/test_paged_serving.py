"""Paged-KV continuous-batching serving: pool/scheduler invariants
(property-based where hypothesis is available, seeded otherwise), block
tables vs a dense reference cache, and the end-to-end guarantee that paged
continuous batching generates token-for-token what the dense static path
generates."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.serving import PagePool, PrefixCache, Request, Scheduler
from repro.serving.page_pool import SCRATCH_PAGE


# ---------------------------------------------------------------------------
# PagePool: ref-counted free list
# ---------------------------------------------------------------------------

def test_pool_alloc_free_roundtrip():
    pool = PagePool(num_pages=8, page_size=16)
    assert pool.num_free == 7                       # page 0 reserved
    pages = pool.alloc(3)
    assert len(pages) == 3 and SCRATCH_PAGE not in pages
    assert pool.num_free == 4 and pool.num_allocated == 3
    assert pool.alloc(5) is None                    # admission control
    pool.free(pages)
    assert pool.num_free == 7 and pool.num_allocated == 0
    pool.check_invariants()


def test_pool_refcount_sharing():
    pool = PagePool(num_pages=4, page_size=8)
    pages = pool.alloc(2)
    pool.share(pages)                               # second owner (fork)
    pool.free(pages)                                # first owner releases
    assert pool.num_allocated == 2                  # still held
    pool.check_invariants()
    pool.free(pages)                                # last owner releases
    assert pool.num_free == 3
    pool.check_invariants()


def test_pool_double_free_raises():
    pool = PagePool(num_pages=4, page_size=8)
    pages = pool.alloc(1)
    pool.free(pages)
    with pytest.raises(ValueError, match="double free"):
        pool.free(pages)
    with pytest.raises(ValueError, match="unallocated"):
        pool.share([2])
    pool.check_invariants()


def test_pool_pages_for():
    pool = PagePool(num_pages=4, page_size=16)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2


# ---------------------------------------------------------------------------
# Scheduler traces: no page leaked or double-freed, tables consistent
# ---------------------------------------------------------------------------

def drive_trace(reqs, num_pages=16, page_size=8, max_batch=3,
                prefill_chunk=4, check_every_step=True,
                prefix_cache=False):
    """Run a full admit/prefill/decode/retire trace without a model:
    generation is faked by appending dummy token ids. Returns the
    scheduler after the trace drains. With ``prefix_cache`` retirement
    parks pages in a radix trie instead of freeing them."""
    pool = PagePool(num_pages, page_size)
    sched = Scheduler(pool, max_batch=max_batch,
                      max_pages=pool.pages_for(64),
                      prefill_chunk=prefill_chunk,
                      prefix_cache=PrefixCache(pool) if prefix_cache
                      else None)
    for r in reqs:
        sched.submit(r)
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 10_000, "trace did not drain"
        sched.retire_finished()
        sched.admit()
        chunk = sched.next_prefill()
        if chunk is not None:
            b, tokens, start, valid = chunk
            sched.mark_prefilled(b, valid)
            seq = sched.slots[b]
            if seq.prompt_done:
                seq.req.tokens.append(1)
        mask = sched.decode_mask()
        for b in np.nonzero(mask)[0]:
            sched.slots[int(b)].req.tokens.append(1)
        sched.advance_decoded(mask)
        if check_every_step:
            sched.check_invariants()
    sched.retire_finished()
    sched.check_invariants()
    return sched


def _mk_reqs(spec):
    return [Request(rid=i, prompt=np.arange(1, p + 1, dtype=np.int32),
                    max_new_tokens=g) for i, (p, g) in enumerate(spec)]


def test_trace_drains_and_recycles_pages():
    sched = drive_trace(_mk_reqs([(5, 3), (12, 1), (1, 6), (20, 4),
                                  (7, 2), (3, 3)]))
    assert len(sched.finished) == 6
    assert sched.pool.num_allocated == 0            # everything recycled
    for r in sched.finished:
        assert len(r.tokens) == r.max_new_tokens


def test_admission_blocks_under_pool_pressure_then_recovers():
    # Pool fits ~one big request at a time: admission must serialize
    # without leaking or deadlocking.
    sched = drive_trace(_mk_reqs([(30, 4), (30, 4), (30, 4)]),
                        num_pages=7, page_size=8, max_batch=3)
    assert len(sched.finished) == 3
    assert sched.pool.num_allocated == 0


def test_oversized_request_rejected():
    # Oversized submissions complete as FAILED results, never exceptions:
    # one bad request in a replayed trace must not abort the whole run.
    from repro.serving import RequestState

    pool = PagePool(8, 8)
    sched = Scheduler(pool, max_batch=2, max_pages=2, prefill_chunk=4)
    req = Request(rid=0, prompt=np.ones(30, np.int32), max_new_tokens=8)
    sched.submit(req)
    assert req.state is RequestState.FAILED
    assert "pages > table width" in req.failure_reason
    assert req in sched.finished
    assert not sched.waiting and not sched.has_work()


@given(st.lists(st.tuples(st.integers(1, 24), st.integers(1, 6)),
                min_size=1, max_size=12),
       st.integers(1, 4), st.sampled_from([4, 8]), st.sampled_from([2, 4, 8]))
@settings(max_examples=25, deadline=None)
def test_property_no_leak_no_double_free(spec, max_batch, page_size, chunk):
    """Random admit/finish traces: every page is either free or owned by
    exactly one live sequence at every step, and the pool is whole after
    the trace drains (checked inside drive_trace each step)."""
    sched = drive_trace(_mk_reqs(spec), num_pages=16, page_size=page_size,
                        max_batch=max_batch, prefill_chunk=chunk)
    assert len(sched.finished) == len(spec)
    assert sched.pool.num_allocated == 0
    assert sched.pool.num_free == sched.pool.num_pages - 1


# ---------------------------------------------------------------------------
# Share/free/fork traces interleaved with admission bursts: the refcount
# machinery (prefix caching / beam forks) must keep the pool whole under
# arbitrary interleavings, not just the scheduler's own alloc/free pattern.
# ---------------------------------------------------------------------------

def drive_fork_trace(ops, num_pages=16, page_size=8, max_batch=3):
    """Interpret a trace of (op, arg) steps against a PagePool plus a
    shadow ownership model, checking ``check_invariants`` AND shadow
    agreement after every step.

    Ops: ("burst", n)  — admission burst: up to n allocations of 1-3 pages
         ("fork", i)   — share() handle i's pages (new owner, beam fork)
         ("free", i)   — release handle i (indices wrap over live handles)
    Returns the pool and the live-handle list (caller drains + re-checks).
    """
    pool = PagePool(num_pages, page_size)
    handles = []                       # each: list of pages owned once

    def check():
        pool.check_invariants()
        want = {}
        for h in handles:
            for p in h:
                want[p] = want.get(p, 0) + 1
        for p in range(1, num_pages):
            assert pool.refcount(p) == want.get(p, 0), \
                f"page {p}: pool says {pool.refcount(p)}, shadow {want.get(p, 0)}"

    for op, arg in ops:
        if op == "burst":
            for k in range(arg):
                pages = pool.alloc(1 + (k % 3))
                if pages is None:
                    break              # admission control, not an error
                handles.append(pages)
        elif op == "fork" and handles:
            src = handles[arg % len(handles)]
            pool.share(src)
            handles.append(list(src))
        elif op == "free" and handles:
            pool.free(handles.pop(arg % len(handles)))
        check()
    return pool, handles


def _drain(pool, handles):
    while handles:
        pool.free(handles.pop())
        pool.check_invariants()
    assert pool.num_allocated == 0
    assert pool.num_free == pool.num_pages - 1


def test_fork_trace_seeded():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.integers(1, 30))
        ops = [(("burst", "fork", "free")[int(rng.integers(0, 3))],
                int(rng.integers(0, 6))) for _ in range(n)]
        pool, handles = drive_fork_trace(
            ops, num_pages=int(rng.integers(4, 24)),
            page_size=int(rng.choice([4, 8])))
        _drain(pool, handles)


@given(st.lists(st.tuples(st.sampled_from(["burst", "fork", "free"]),
                          st.integers(0, 6)), min_size=1, max_size=40),
       st.integers(4, 24))
@settings(max_examples=50, deadline=None)
def test_property_fork_traces_keep_pool_whole(ops, num_pages):
    """Every interleaving of admission bursts, prefix forks, and frees
    keeps refcounts exact and the pool leak-free at every step."""
    pool, handles = drive_fork_trace(ops, num_pages=num_pages)
    _drain(pool, handles)


def test_scheduler_trace_with_shared_prefix_pages():
    """A scheduler trace runs to completion while an external owner holds
    share()d references to admitted sequences' pages (prefix cache): the
    scheduler's frees release its ownership only, the pages survive until
    the external owner lets go, and invariants hold at every step."""
    pool = PagePool(24, 8)
    sched = Scheduler(pool, max_batch=2, max_pages=pool.pages_for(64),
                      prefill_chunk=4)
    for r in _mk_reqs([(6, 3), (10, 2), (4, 4), (9, 1)]):
        sched.submit(r)
    forked = []
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 10_000
        sched.retire_finished()
        for b in sched.admit():
            # fork every admitted sequence's pages (prefix cache holds on)
            pages = sched.slots[b].pages
            pool.share(pages)
            forked.append(list(pages))
        chunk = sched.next_prefill()
        if chunk is not None:
            b, tokens, start, valid = chunk
            sched.mark_prefilled(b, valid)
            if sched.slots[b].prompt_done:
                sched.slots[b].req.tokens.append(1)
        mask = sched.decode_mask()
        for b in np.nonzero(mask)[0]:
            sched.slots[int(b)].req.tokens.append(1)
        sched.advance_decoded(mask)
        sched.check_invariants()
    sched.retire_finished()
    sched.check_invariants()
    # Scheduler released its ownerships; the forked prefixes still pin
    # every page they reference (held pages are never recycled, so each
    # admission got fresh pages and the forked sets are disjoint).
    assert len(sched.finished) == 4
    assert pool.num_allocated == len({p for f in forked for p in f})
    for f in forked:
        pool.free(f)
        pool.check_invariants()
    assert pool.num_allocated == 0
    assert pool.num_free == pool.num_pages - 1


# ---------------------------------------------------------------------------
# Prefix-cached scheduler traces: marginal admission accounting, eviction
# under pressure, and forks composing with cache hits (PR 5 share/free
# machinery under the radix trie). Trie-level unit and shadow-model tests
# live in tests/test_prefix_cache.py.
# ---------------------------------------------------------------------------

def test_cached_trace_prefill_accounting_is_exact():
    """Every prompt token is either computed by a prefill chunk or served
    from a cached page — never both, never neither: over a whole trace
    ``total_prefill_tokens + total_cached_tokens == sum(prompt lens)``,
    and only the parked pages survive the drain."""
    specs = [([(5, 3), (12, 1), (1, 6), (20, 4), (7, 2), (3, 3)], 3),
             ([(16, 2), (16, 2), (16, 2)], 1),     # identical, serialized
             ([(24, 1), (8, 5), (24, 1), (9, 2)], 2)]
    for spec, max_batch in specs:      # _mk_reqs prompts share prefixes
        sched = drive_trace(_mk_reqs(spec), prefix_cache=True,
                            max_batch=max_batch, page_size=4)
        cache = sched.prefix_cache
        assert len(sched.finished) == len(spec)
        assert sched.total_prefill_tokens + sched.total_cached_tokens \
            == sum(p for p, _ in spec)
        assert sched.total_cached_tokens > 0       # sharing happened
        assert sched.pool.num_allocated == cache.num_pages
        cache.drop()
        assert sched.pool.num_allocated == 0


def test_cached_trace_marginal_admission_only():
    """The second of two identical requests is charged only its marginal
    pages: the free-list drop at admission is total-need minus the cached
    full pages of its prompt."""
    pool = PagePool(32, 8)
    cache = PrefixCache(pool)
    sched = Scheduler(pool, max_batch=1, max_pages=pool.pages_for(64),
                      prefill_chunk=4, prefix_cache=cache)
    prompt = np.arange(1, 25, dtype=np.int32)      # 24 tokens, 3 pages
    for r in _mk_reqs([(24, 3)]):
        sched.submit(r)
    _drain_sched(sched)
    free_before = pool.num_free
    req = Request(rid=9, prompt=prompt, max_new_tokens=3)
    sched.submit(req)
    sched.admit()
    seq = sched.slots[0]
    # limit = 23 caps the hit at 2 full pages (16 tokens)
    assert seq.cached_tokens == 16
    # Optimistic admission charges the chunk-padded PREFILL view only
    # (decode grows pages on demand), minus the cached full pages.
    need = pool.pages_for(-(-req.prompt_len // 4) * 4)
    assert free_before - pool.num_free == need - 2
    _drain_sched(sched)


def test_cached_trace_evicts_under_pressure():
    """Disjoint-prefix requests through a pool that can't hold a request
    plus the previous request's parked pages: admission must evict LRU
    trie pages (never deadlock), with invariants held at every step."""
    reqs = [Request(rid=i,
                    prompt=np.arange(100 * i, 100 * i + 24,
                                     dtype=np.int32),
                    max_new_tokens=2) for i in range(4)]
    pool = PagePool(6, 8)              # 5 usable; each request needs 4
    cache = PrefixCache(pool)
    sched = Scheduler(pool, max_batch=1, max_pages=pool.pages_for(64),
                      prefill_chunk=4, prefix_cache=cache)
    for r in reqs:
        sched.submit(r)
    _drain_sched(sched)
    assert len(sched.finished) == 4
    assert cache.stats()["evicted_pages"] >= 6     # 2 per later admission
    assert pool.num_allocated == cache.num_pages


def test_fork_after_hit_outlives_eviction():
    """A fork taken on a cache hit (beam fork / a second live request)
    pins the pages: the trie cannot evict them while the fork holds its
    ownership, and they return to the free list only after BOTH the trie
    and the fork let go."""
    sched = drive_trace(_mk_reqs([(16, 2)]), prefix_cache=True)
    cache, pool = sched.prefix_cache, sched.pool
    assert cache.num_pages == 2                    # 17 resident tokens
    pages, n = cache.match(np.arange(1, 17, dtype=np.int32))
    assert n == 16
    pool.share(pages)                              # fork after the hit
    assert cache.drop() == 0                       # pinned: nothing evicts
    assert cache.num_pages == 2
    pool.free([pages[1]])                          # fork releases the tail
    assert cache.drop() == 1                       # tail leaf now evicts
    sched.check_invariants()
    pool.free([pages[0]])
    assert cache.drop() == 1
    assert pool.num_allocated == 0
    pool.check_invariants()


def _drain_sched(sched):
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 10_000, "trace did not drain"
        sched.retire_finished()
        sched.admit()
        chunk = sched.next_prefill()
        if chunk is not None:
            b, tokens, start, valid = chunk
            sched.mark_prefilled(b, valid)
            if sched.slots[b].prompt_done:
                sched.slots[b].req.tokens.append(1)
        mask = sched.decode_mask()
        for b in np.nonzero(mask)[0]:
            sched.slots[int(b)].req.tokens.append(1)
        sched.advance_decoded(mask)
        sched.check_invariants()


# ---------------------------------------------------------------------------
# Block tables vs a dense reference cache (scatter/gather consistency)
# ---------------------------------------------------------------------------

def _scatter_gather_roundtrip(B, lens_np, page_size, seed):
    import jax.numpy as jnp

    from repro.kernels.ref import gather_pages
    from repro.models.attention import _gather_pages_bthd, _scatter_pages

    rng = np.random.default_rng(seed)
    Hkv, D = 2, 8
    max_len = int(max(lens_np))
    NB = -(-max_len // page_size)
    pool_pages = 1 + B * NB
    tables = np.zeros((B, NB), np.int32)
    nxt = 1
    for b in range(B):                       # ragged ownership, page 0 scratch
        need = -(-int(lens_np[b]) // page_size)
        tables[b, :need] = np.arange(nxt, nxt + need)
        nxt += need
    pool = jnp.zeros((Hkv, pool_pages, page_size, D), jnp.float32)
    dense = np.zeros((B, max_len, Hkv, D), np.float32)
    # Write each sequence in two ragged chunks, like chunked prefill.
    tbl = jnp.asarray(tables)
    for b in range(B):
        L = int(lens_np[b])
        split = rng.integers(0, L + 1)
        for lo, hi in ((0, split), (split, L)):
            if hi == lo:
                continue
            vals = rng.standard_normal((1, hi - lo, Hkv, D)).astype(np.float32)
            pool = _scatter_pages(pool, jnp.asarray(vals), tbl[b:b + 1],
                                  jnp.asarray([lo], jnp.int32))
            dense[b, lo:hi] = vals[0]
    got = np.asarray(_gather_pages_bthd(pool, tbl))     # (B, NB*ps, Hkv, D)
    for b in range(B):
        L = int(lens_np[b])
        np.testing.assert_array_equal(got[b, :L], dense[b, :L])
    # ref.gather_pages agrees with the model-side gather (kernel layout).
    got2 = np.asarray(gather_pages(pool, tbl))          # (B, Hkv, T, D)
    np.testing.assert_array_equal(np.moveaxis(got2, 1, 2), got)


def test_block_tables_match_dense_cache_seeded():
    _scatter_gather_roundtrip(3, np.array([5, 17, 24]), page_size=8, seed=0)
    _scatter_gather_roundtrip(2, np.array([1, 31]), page_size=16, seed=1)


@given(st.lists(st.integers(1, 40), min_size=1, max_size=4),
       st.sampled_from([4, 8, 16]), st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_property_block_tables_match_dense_cache(lens, page_size, seed):
    _scatter_gather_roundtrip(len(lens), np.array(lens), page_size, seed)


# ---------------------------------------------------------------------------
# End-to-end: paged continuous batching == dense static decode, token for
# token, on seeded random traffic
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="paged-t", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=128, dtype="float32")


def _dense_greedy(params, cfg, prompt, gen):
    import jax
    import jax.numpy as jnp

    from repro.models import lm
    toks = jnp.asarray(prompt[None], jnp.int32)
    P = len(prompt)
    lg, cache = lm.prefill(params, cfg, toks, max_len=P + gen,
                           opts=lm.ForwardOpts(attn_impl="full"))
    out = [int(jnp.argmax(lg[0]))]
    for i in range(gen - 1):
        lg, cache = lm.decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), cache,
            jnp.int32(P + i), opts=lm.ForwardOpts(decode_impl="full"))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_paged_engine_matches_dense_reference():
    import jax

    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    rng = np.random.default_rng(42)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, int(p))
                    .astype(np.int32),
                    max_new_tokens=int(g))
            for i, (p, g) in enumerate(
                zip(rng.integers(2, 10, 5), rng.integers(1, 5, 5)))]
    engine = ServingEngine(cfg, params, num_pages=24, page_size=8,
                           max_batch=3, max_seq_len=24, prefill_chunk=4)
    res = engine.run(reqs)
    assert res["requests"] == len(reqs)
    engine.scheduler.check_invariants()
    assert engine.pool.num_allocated == 0
    for r in sorted(engine.scheduler.finished, key=lambda r: r.rid):
        want = _dense_greedy(params, cfg, r.prompt, r.max_new_tokens)
        assert r.tokens == want, \
            f"req {r.rid}: paged {r.tokens} != dense {want}"


def test_paged_engine_requires_supported_arch():
    import dataclasses

    import jax

    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(_tiny_cfg(), window=8)
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    with pytest.raises(NotImplementedError, match="paged serving"):
        ServingEngine(cfg, params, num_pages=8, page_size=8,
                      max_batch=1, max_seq_len=16, prefill_chunk=4)
