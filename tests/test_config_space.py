"""ConfigSpace / constraint / pruning tests (paper Q4.1) + hypothesis
properties."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.core import ConfigSpace, Param, TuningContext, get_chip
from repro.core.config_space import (
    at_most_dim, divides, dtype_bytes, lane_aligned, multiple_of, ordered,
    sublane_aligned, vmem_fits,
)


def ctx(chip="tpu_v5e", **shapes):
    return TuningContext(chip=get_chip(chip), shapes=shapes)


def simple_space():
    sp = ConfigSpace("t", [Param("a", (1, 2, 4)), Param("b", (8, 16))])
    sp.constrain("a<=b", lambda c, x: c["a"] <= c["b"])
    return sp


def test_cardinality_and_enumeration():
    sp = simple_space()
    assert sp.cardinality == 6
    cfgs = list(sp.iter_all())
    assert len(cfgs) == 6
    assert all(set(c) == {"a", "b"} for c in cfgs)


def test_constraints_prune():
    sp = ConfigSpace("t", [Param("a", (1, 64))])
    sp.constrain("too_big", lambda c, x: c["a"] <= 8)
    valid = sp.valid_configs(ctx(x=(16,)))
    assert valid == [{"a": 1}]
    rep = sp.pruning_report(ctx(x=(16,)))
    assert rep == {"valid": 1, "too_big": 1}


def test_default_is_first_valid():
    sp = simple_space()
    assert sp.default(ctx()) == {"a": 1, "b": 8}


def test_no_valid_config_raises():
    sp = ConfigSpace("t", [Param("a", (1,))])
    sp.constrain("never", lambda c, x: False)
    with pytest.raises(ValueError):
        sp.default(ctx())


def test_duplicate_param_rejected():
    with pytest.raises(ValueError):
        ConfigSpace("t", [Param("a", (1,)), Param("a", (2,))])


def test_empty_domain_rejected():
    with pytest.raises(ValueError):
        Param("a", ())


def test_vmem_constraint_is_chip_conditional():
    """Paper Fig. 4: configs valid on one platform are invalid on another."""
    sp = ConfigSpace("t", [Param("blk", (128, 4096))])
    sp.constrain("vmem", vmem_fits(lambda c, x: c["blk"] * 4096))
    v5e = sp.valid_configs(ctx("tpu_v5e"))
    v4 = sp.valid_configs(ctx("tpu_v4"))
    assert {"blk": 4096} in v5e
    assert {"blk": 4096} not in v4          # 32 MiB > 16 MiB budget
    assert {"blk": 128} in v4


def test_constraint_builders():
    c = ctx(x=(256, 128))
    assert divides("p", "x", 0)({"p": 64}, c)
    assert not divides("p", "x", 0)({"p": 96}, c)
    assert at_most_dim("p", "x", 1)({"p": 128}, c)
    assert not at_most_dim("p", "x", 1)({"p": 256}, c)
    assert multiple_of("p", 8)({"p": 64}, c)
    assert lane_aligned("p")({"p": 256}, c)
    assert not lane_aligned("p")({"p": 100}, c)
    assert sublane_aligned("p")({"p": 8}, c)
    assert ordered("p", "q")({"p": 2, "q": 4}, c)
    assert dtype_bytes("bfloat16") == 2


def test_space_hash_changes_with_version():
    a = ConfigSpace("t", [Param("a", (1,))], version=1)
    b = ConfigSpace("t", [Param("a", (1,))], version=2)
    assert a.space_hash() != b.space_hash()


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

@st.composite
def spaces(draw):
    n = draw(st.integers(1, 3))
    params = []
    for i in range(n):
        vals = draw(st.lists(st.integers(1, 64), min_size=1, max_size=4,
                             unique=True))
        params.append(Param(f"p{i}", tuple(vals)))
    return ConfigSpace("h", params)


@given(spaces(), st.integers(0, 2 ** 31))
@settings(max_examples=50, deadline=None)
def test_valid_subset_of_all(sp, threshold):
    sp.constrain("thresh", lambda c, x: sum(c.values()) % 7 != threshold % 7)
    c = ctx()
    all_cfgs = list(sp.iter_all())
    valid = sp.valid_configs(c)
    assert len(all_cfgs) == sp.cardinality
    for cfg in valid:
        assert sp.is_valid(cfg, c)
        assert cfg in all_cfgs
    for cfg in all_cfgs:
        why = sp.why_invalid(cfg, c)
        assert (why is None) == (cfg in valid)


@given(spaces())
@settings(max_examples=30, deadline=None)
def test_pruning_report_partitions_space(sp):
    sp.constrain("even", lambda c, x: sum(c.values()) % 2 == 0)
    rep = sp.pruning_report(ctx())
    assert sum(rep.values()) == sp.cardinality
