"""Shipped tuning DB validity: every entry must parse against its
kernel's CURRENT config space.

The shipped DB is machine-generated and long-lived; kernels evolve. A
renamed tunable, a dropped domain value, a version bump, or an edited
constraint silently turns shipped entries into dead weight (the cache's
space-hash check makes them misses — correct, but then every deployment
cold-tunes at startup and nobody notices at PR time). This suite turns
that rot into a test failure the moment it is introduced."""

import json
import os

from repro.core.cache import CacheEntry, cache_key
from repro.core.config_space import TuningContext
from repro.core.hardware import get_chip
from repro.kernels.registry import get_kernel

DB_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro",
                       "configs", "shipped_tuning_db.json")


def _load():
    with open(DB_PATH) as f:
        return json.load(f)


def _parse_key(key):
    k = json.loads(key)
    ctx_payload = json.loads(k["ctx"])
    ctx = TuningContext(
        chip=get_chip(ctx_payload["chip"]),
        shapes={n: tuple(v) for n, v in ctx_payload["shapes"].items()},
        dtype=ctx_payload["dtype"],
        extra=dict(ctx_payload["extra"]),
        mesh=dict(ctx_payload.get("mesh", {})),
    )
    return k, ctx


def test_db_loads_and_is_not_tiny():
    db = _load()
    assert len(db) > 300, f"shipped DB suspiciously small: {len(db)}"


def test_every_entry_parses_against_current_config_space():
    """The PR-time gate: kernel exists, version and space hash are
    current, the stored config is valid for the reconstructed context,
    and the signature round-trips (so runtime lookups can actually hit
    the key as written)."""
    db = _load()
    assert db, "empty shipped DB"
    for key, raw in db.items():
        k, ctx = _parse_key(key)
        spec = get_kernel(k["kernel"])          # raises for renamed kernels
        tk = spec.tunable
        assert k["kernel_version"] == tk.version, \
            f"{k['kernel']}: shipped at version {k['kernel_version']}, " \
            f"kernel is now {tk.version} — regenerate the DB"
        assert k["space"] == tk.space.space_hash(), \
            f"{k['kernel']}: config space changed since the DB was " \
            f"generated (dead/renamed tunables?) — regenerate the DB"
        entry = CacheEntry.from_json(raw)
        assert not entry.failed(), \
            f"{k['kernel']}: shipped a failed search for {ctx.signature()}"
        why = tk.space.why_invalid(entry.config, ctx)
        assert why is None, \
            f"{k['kernel']}: shipped config {entry.config} violates " \
            f"constraint {why!r} for {ctx.signature()}"
        # Round-trip: rebuilding the key from parsed parts reproduces it,
        # so a runtime lookup with this context hits this entry.
        assert cache_key(k["kernel"], k["kernel_version"], tk.space,
                         ctx) == key


def test_entries_cover_every_chip_generation():
    from repro.configs.gen_shipped_db import CHIPS as SHIP_CHIPS
    db = _load()
    chips = {json.loads(json.loads(k)["ctx"])["chip"] for k in db}
    assert chips == set(SHIP_CHIPS), chips


def test_tp_deployment_entries_shipped():
    """TP=2 and TP=4 sharded serving deployments ship warm (DESIGN.md
    §11): mesh-signature keys exist for the decode serving family, and
    each sharded paged_decode scenario has a float and an int8 variant."""
    db = _load()
    by_mesh = {}
    for key in db:
        k, ctx = _parse_key(key)
        tp = ctx.mesh.get("model", 1)
        by_mesh.setdefault(tp, set()).add((k["kernel"], ctx.dtype))
    assert set(by_mesh) == {1, 2, 4}, sorted(by_mesh)
    for tp in (2, 4):
        assert ("paged_decode", "bfloat16") in by_mesh[tp]
        assert ("paged_decode", "int8") in by_mesh[tp]
        assert ("gqa_decode_ragged", "bfloat16") in by_mesh[tp]
        assert ("gqa_decode_kv8", "int8") in by_mesh[tp]


def test_sharded_entries_use_local_shapes():
    """A TP entry's shapes must be the per-shard view: for every arch
    that shipped a TP=N paged_decode entry, an unsharded entry with N×
    the head counts exists — the global scenario it was derived from."""
    db = _load()
    plain, sharded = set(), []
    for key in db:
        k, ctx = _parse_key(key)
        if k["kernel"] != "paged_decode" or ctx.dtype != "bfloat16":
            continue
        hq, hkv = ctx.shape("q")[1], ctx.shape("k")[1]
        tp = ctx.mesh.get("model", 1)
        if tp == 1:
            plain.add((ctx.chip.name, hq, hkv))
        else:
            sharded.append((ctx.chip.name, hq, hkv, tp))
    assert sharded, "no sharded paged_decode entries"
    for chip, hq, hkv, tp in sharded:
        assert (chip, hq * tp, hkv * tp) in plain, \
            f"TP={tp} entry ({hq},{hkv}) has no parent global entry"


def test_deployment_lookup_context_matches_shipped_key():
    """serve.py's paged deployment lookup must reconstruct EXACTLY a
    shipped context — shapes, dtype, and mesh signature — or warm starts
    silently break. Pin it for a known-divisible arch at TP=1/2/4."""
    from repro.configs import get_config
    from repro.configs.gen_shipped_db import (
        SHIP_DTYPE, paged_deployment_shapes, tp_mesh_signature,
    )
    db = _load()
    cfg = get_config("phi3-mini-3.8b")
    kernel = get_kernel("paged_decode").tunable
    for tp in (1, 2, 4):
        ctx = TuningContext(chip=get_chip("tpu_v5e"),
                            shapes=paged_deployment_shapes(cfg, tp=tp),
                            dtype=SHIP_DTYPE, mesh=tp_mesh_signature(tp))
        key = cache_key(kernel.name, kernel.version, kernel.space, ctx)
        assert key in db, f"no shipped TP={tp} deployment entry for phi3"


# ---------------------------------------------------------------------------
# Shipped config portfolio (configs/shipped_portfolio.json): the "A Few
# Fit Most" artifact serve.py --config-source portfolio|db dispatches from
# ---------------------------------------------------------------------------

PF_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro",
                       "configs", "shipped_portfolio.json")


def _load_pf():
    with open(PF_PATH) as f:
        return json.load(f)


def test_portfolio_artifact_current_and_in_space():
    """Every kernel section references the kernel's CURRENT version and
    space hash (stale sections are dead weight the selector refuses to
    serve), every member binds exactly the space's tunables to in-domain
    values, and every selector target points at a real member."""
    pf = _load_pf()
    from repro.core.portfolio import PORTFOLIO_SCHEMA
    assert pf["schema"] == PORTFOLIO_SCHEMA
    assert pf["kernels"], "empty portfolio"
    for name, sec in pf["kernels"].items():
        tk = get_kernel(name).tunable           # raises for renamed kernels
        assert sec["version"] == tk.version, \
            f"{name}: portfolio at version {sec['version']}, kernel is " \
            f"now {tk.version} — regenerate (gen_portfolio)"
        assert sec["space"] == tk.space.space_hash(), \
            f"{name}: config space changed since the portfolio was " \
            f"generated — regenerate (gen_portfolio)"
        domains = {p.name: set(p.values) for p in tk.space.params}
        assert sec["members"], f"{name}: section with no members"
        for m in sec["members"]:
            cfg = m["config"]
            assert set(cfg) == set(domains), \
                f"{name}: member binds {sorted(cfg)} != tunables " \
                f"{sorted(domains)}"
            for p, v in cfg.items():
                assert v in domains[p], \
                    f"{name}: member {p}={v!r} off-domain"
        for sig, idx in sec["selector"].items():
            assert 0 <= idx < len(sec["members"]), \
                f"{name}: selector {sig} -> dangling member {idx}"


def test_portfolio_is_an_order_of_magnitude_smaller_than_db():
    """The artifact only earns its keep if it is actually small: total
    members bounded at a quarter of the point-entry count (in practice
    it ships far below that) and every DB kernel is represented."""
    db, pf = _load(), _load_pf()
    n_members = sum(len(s["members"]) for s in pf["kernels"].values())
    assert n_members <= 0.25 * len(db), \
        f"{n_members} members vs {len(db)} point entries"
    db_kernels = {json.loads(k)["kernel"] for k in db}
    assert set(pf["kernels"]) == db_kernels


def test_portfolio_deployment_lookup_round_trip():
    """The serve.py --config-source portfolio path end-to-end: for the
    known-divisible phi3 arch at TP=1/2/4, the deployment context built
    exactly as serve.py builds it gets an EXACT selector hit (not the
    nearest-neighbor fallback) and a member valid for that context."""
    from repro.configs import get_config
    from repro.configs.gen_shipped_db import (
        SHIP_DTYPE, paged_deployment_shapes, tp_mesh_signature,
    )
    from repro.core.portfolio import Portfolio, scenario_features
    pf = Portfolio.load_shipped()
    assert pf is not None, "shipped_portfolio.json missing"
    cfg = get_config("phi3-mini-3.8b")
    kernel = get_kernel("paged_decode").tunable
    sec = pf.data["kernels"]["paged_decode"]
    members = {json.dumps(m["config"], sort_keys=True)
               for m in sec["members"]}
    for tp in (1, 2, 4):
        ctx = TuningContext(chip=get_chip("tpu_v5e"),
                            shapes=paged_deployment_shapes(cfg, tp=tp),
                            dtype=SHIP_DTYPE, mesh=tp_mesh_signature(tp))
        assert scenario_features(ctx) in sec["selector"], \
            f"TP={tp} deployment scenario missing from selector"
        got = pf.select(kernel, ctx)
        assert got is not None
        assert json.dumps(got, sort_keys=True) in members
        assert kernel.space.why_invalid(got, ctx) is None
    st = pf.stats()
    assert st["exact_hits"] == 3 and st["nearest_hits"] == 0


def test_portfolio_selector_covers_tp_meshes():
    """TP=1/2/4 mesh signatures all appear among the decode-family
    selector scenarios — sharded serving resolves portfolio members
    without falling back to nearest-neighbor guessing."""
    pf = _load_pf()
    meshes = set()
    for name in ("paged_decode", "gqa_decode_ragged", "gqa_decode_kv8"):
        for sig in pf["kernels"][name]["selector"]:
            feat = json.loads(sig)
            meshes.add(feat.get("mesh", {}).get("model", 1))
    assert {1, 2, 4} <= meshes, sorted(meshes)
