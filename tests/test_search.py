"""Search strategies (paper Q4.2): correctness + hypothesis invariants."""

import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.core import (
    ConfigSpace, EvolutionarySearch, ExhaustiveSearch, Param, RandomSearch,
    SuccessiveHalving, TuningContext, get_chip, make_strategy,
)


def space():
    return ConfigSpace("s", [Param("a", (1, 2, 4, 8, 16)),
                             Param("b", (1, 2, 4, 8))])


def ctx():
    return TuningContext(chip=get_chip("tpu_v5e"), shapes={})


def bowl(cfg, fidelity=1):
    # Smooth landscape, optimum at a=4, b=2.
    return (cfg["a"] - 4) ** 2 + (cfg["b"] - 2) ** 2 + 0.1


def test_exhaustive_finds_optimum():
    res = ExhaustiveSearch().run(space(), ctx(), bowl)
    assert res.best == {"a": 4, "b": 2}
    assert res.evaluations == 20


def test_exhaustive_budget_cap():
    res = ExhaustiveSearch(max_configs=5).run(space(), ctx(), bowl)
    assert res.evaluations == 5


def test_random_budget():
    res = RandomSearch(budget=10, seed=1).run(space(), ctx(), bowl)
    assert res.evaluations == 10
    assert res.best is not None


def test_evolutionary_converges_on_smooth_landscape():
    res = EvolutionarySearch(population=4, generations=8, children=6,
                             seed=0).run(space(), ctx(), bowl)
    assert res.best_metric <= 1.2   # at/near the bowl bottom
    assert res.evaluations < 20     # cheaper than exhaustive (dedup works)


def test_successive_halving_raises_fidelity():
    fidelities = []

    def noisy(cfg, fidelity=1):
        fidelities.append(fidelity)
        return bowl(cfg)

    res = SuccessiveHalving(initial=12, rungs=3, base_fidelity=1,
                            fidelity_mult=4).run(space(), ctx(), noisy)
    assert res.best is not None
    assert max(fidelities) >= 4     # survivors re-measured more precisely


def test_failed_measurements_are_skipped():
    def flaky(cfg, fidelity=1):
        if cfg["a"] == 4:
            return math.inf
        return bowl(cfg)

    res = ExhaustiveSearch().run(space(), ctx(), flaky)
    assert res.best["a"] != 4


def test_all_failed_gives_none():
    res = ExhaustiveSearch().run(space(), ctx(),
                                 lambda c, fidelity=1: math.inf)
    assert res.best is None


def test_make_strategy_registry():
    for name in ("exhaustive", "random", "evolutionary",
                 "successive_halving"):
        kwargs = {"budget": 4} if name == "random" else {}
        assert make_strategy(name, **kwargs).name == name


@given(st.integers(0, 1000), st.sampled_from(["random", "evolutionary",
                                              "successive_halving"]))
@settings(max_examples=25, deadline=None)
def test_searchers_return_valid_configs(seed, strat_name):
    sp = space()
    sp.constrain("a!=8", lambda c, x: c["a"] != 8)
    kwargs = {"seed": seed}
    if strat_name == "random":
        kwargs["budget"] = 6
    strat = make_strategy(strat_name, **kwargs)
    res = strat.run(sp, ctx(), bowl)
    assert res.best is not None
    assert sp.is_valid(res.best, ctx())
    # Reported best is the min over everything it measured.
    measured = [t.metric for t in res.trials if t.ok()]
    assert math.isclose(res.best_metric, min(measured))


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_search_deterministic_given_seed(seed):
    a = RandomSearch(budget=8, seed=seed).run(space(), ctx(), bowl)
    b = RandomSearch(budget=8, seed=seed).run(space(), ctx(), bowl)
    assert a.best == b.best and a.best_metric == b.best_metric
