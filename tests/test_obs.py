"""Observability layer (repro/obs/): tracer ring buffer + Chrome export
pinned byte-for-byte against a golden fixture (tests/fixtures/obs_trace/),
histogram bucket math, metrics registry snapshot/Prometheus shape, drift
detector flag/silence behavior, bounded token-time recording, and the
contract that turning instrumentation on changes ZERO generated tokens."""

import json
import math
import os

import numpy as np
import pytest

from repro.obs import (
    Counter, DriftDetector, Gauge, Histogram, MetricsRegistry, Tracer,
    VirtualClock,
)
from repro.obs import drift as drift_lib
from repro.obs import trace as trace_lib
from repro.serving import PagePool, Request, Scheduler
from repro.serving.scheduler import TOKEN_TIMES_CAP, latency_summary

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "obs_trace")


# ---------------------------------------------------------------------------
# Tracer: virtual clock, ring buffer, Chrome trace shape
# ---------------------------------------------------------------------------

def test_virtual_clock_ticks_deterministically():
    clk = VirtualClock(step=1e-6)
    assert [round(clk() * 1e6) for _ in range(4)] == [1, 2, 3, 4]
    clk2 = VirtualClock(step=0.5, start=10.0)
    assert clk2() == 10.5 and clk2() == 11.0


def test_tracer_span_nesting_and_instants():
    tr = Tracer(clock=VirtualClock())
    with tr.span("outer", track="sched", a=1):
        tr.instant("tick", track="sched", n=7)
        with tr.span("inner", track="sched"):
            pass
    phs = [(e["name"], e["ph"]) for e in tr.events]
    assert phs == [("outer", "B"), ("tick", "i"), ("inner", "B"),
                   ("inner", "E"), ("outer", "E")]
    ts = [e["ts"] for e in tr.events]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)  # strictly increasing
    assert tr.events[1]["args"] == {"n": 7}
    assert tr.events[0]["args"] == {"a": 1}


def test_tracer_ring_buffer_drops_oldest():
    tr = Tracer(clock=VirtualClock(), capacity=4)
    for i in range(7):
        tr.instant(f"e{i}")
    assert len(tr.events) == 4
    assert tr.dropped == 3
    assert [e["name"] for e in tr.events] == ["e3", "e4", "e5", "e6"]
    chrome = tr.to_chrome()
    assert chrome["metadata"] == {"dropped_events": 3, "capacity": 4}


def test_tracer_chrome_export_shape(tmp_path):
    tr = Tracer(clock=VirtualClock())
    tr.begin("req0", track="slot0", rid=0)
    tr.instant("cache_hit", track="tuner", kernel="paged_decode")
    tr.end("req0", track="slot0")
    path = str(tmp_path / "trace.json")
    tr.export(path)
    with open(path) as f:
        chrome = json.load(f)
    evs = chrome["traceEvents"]
    assert chrome["displayTimeUnit"] == "ms"
    # one thread_name metadata event per track, tids stable by creation
    meta = [e for e in evs if e["ph"] == "M"]
    assert [(m["tid"], m["args"]["name"]) for m in meta] == \
        [(0, "slot0"), (1, "tuner")]
    assert all(e["pid"] == 0 for e in evs)
    b, i, e = [ev for ev in evs if ev["ph"] in "BiE"]
    assert (b["ph"], i["ph"], e["ph"]) == ("B", "i", "E")
    assert b["tid"] == e["tid"] == 0 and i["tid"] == 1
    assert i["s"] == "t" and i["args"] == {"kernel": "paged_decode"}


def test_active_tracer_helpers_are_noops_when_uninstalled():
    assert trace_lib.get_active() is None
    trace_lib.active_instant("nope")            # must not raise
    with trace_lib.active_span("nope") as tr:
        assert tr is None
    tracer = Tracer(clock=VirtualClock())
    old = trace_lib.set_active(tracer)
    try:
        trace_lib.active_instant("yes", track="t")
        with trace_lib.active_span("s", track="t"):
            pass
        assert [e["name"] for e in tracer.events] == ["yes", "s", "s"]
    finally:
        trace_lib.set_active(old)


# ---------------------------------------------------------------------------
# Golden fixture: an 8-request scheduler trace under the virtual clock
# must export byte-for-byte what the committed fixture pins
# ---------------------------------------------------------------------------

def _golden_trace_text():
    """Drive a seeded 8-request trace through the scheduler (host-only,
    fake generation) with a virtual-clock tracer; return the exported
    Chrome JSON text."""
    tracer = Tracer(clock=VirtualClock())
    pool = PagePool(num_pages=24, page_size=4)
    sched = Scheduler(pool, max_batch=3, max_pages=pool.pages_for(48),
                      prefill_chunk=4, tracer=tracer)
    rng = np.random.default_rng(7)
    for i in range(8):
        sched.submit(Request(
            rid=i,
            prompt=rng.integers(1, 64,
                                int(rng.integers(2, 11))).astype(np.int32),
            max_new_tokens=int(rng.integers(1, 5))))
    guard = 0
    while sched.has_work():
        guard += 1
        assert guard < 10_000, "trace did not drain"
        with tracer.span("step", track="scheduler", step=guard - 1):
            sched.retire_finished()
            sched.admit()
            chunk = sched.next_prefill()
            if chunk is not None:
                b, tokens, start, valid = chunk
                sched.mark_prefilled(b, valid)
                seq = sched.slots[b]
                if seq.prompt_done:
                    seq.req.tokens.append(seq.req.rid % 5 + 1)
            mask = sched.decode_mask()
            for b in np.nonzero(mask)[0]:
                sched.slots[int(b)].req.tokens.append(
                    sched.slots[int(b)].req.rid % 5 + 1)
            sched.advance_decoded(mask)
    sched.check_invariants()
    return json.dumps(tracer.to_chrome(), indent=1, sort_keys=True) + "\n"


def test_golden_chrome_trace():
    """The seeded scheduler trace must reproduce its committed Chrome
    export exactly — any drift in admission order, slot assignment, or
    event emission shows up as a byte diff here."""
    got = _golden_trace_text()
    with open(os.path.join(FIXTURES, "expected_trace.json")) as f:
        want = f.read()
    assert got == want, (
        "obs trace drifted from the golden fixture;\n"
        "if the change is intentional, regenerate with:\n"
        "  PYTHONPATH=src:tests python -c 'import test_obs as t;"
        " print(t._golden_trace_text(), end=\"\")'"
        f"\ngot:\n{got}")


def test_golden_trace_is_balanced_and_loadable():
    chrome = json.loads(_golden_trace_text())
    evs = chrome["traceEvents"]
    # every B has a matching E on the same track, and all 8 requests ran
    opens = {}
    for e in evs:
        if e["ph"] == "B":
            opens.setdefault((e["tid"], e["name"]), []).append(e)
        elif e["ph"] == "E":
            assert opens[(e["tid"], e["name"])], f"unmatched end: {e}"
            opens[(e["tid"], e["name"])].pop()
    assert all(not v for v in opens.values()), "unmatched span begins"
    req_spans = {e["name"] for e in evs
                 if e["ph"] == "B" and e["name"].startswith("req")}
    assert req_spans == {f"req{i}" for i in range(8)}
    # admit/retire are covered by the slot spans, not duplicated as
    # lifecycle instants; submit is the queued-side instant
    assert any(e["name"] == "submit" and e["ph"] == "i" for e in evs)
    assert not any(e["name"] in ("admit", "retire") and e["ph"] == "i"
                   for e in evs)


# ---------------------------------------------------------------------------
# Metrics: counters, gauges, histogram bucket math, registry exports
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    c = Counter("n")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_gauge_up_and_down():
    g = Gauge("depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3.0


def test_histogram_bucket_math():
    h = Histogram("lat", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 4.0, 99.0):   # bounds are inclusive
        h.observe(v)
    assert h.count == 5 and h.sum == 106.0
    assert h.bucket_counts == [2, 1, 1, 1]          # last slot = overflow
    assert h.cumulative() == [(1.0, 2), (2.0, 3), (5.0, 4), (math.inf, 5)]


def test_histogram_quantile_interpolation():
    h = Histogram("lat", buckets=(10.0, 20.0, 40.0))
    for _ in range(8):
        h.observe(5.0)                               # all in first bucket
    assert h.quantile(0.5) == pytest.approx(5.0)     # 0 + 0.5 * 10
    h2 = Histogram("lat2", buckets=(10.0, 20.0))
    h2.observe(5.0)
    h2.observe(15.0)
    # target q=1.0 -> 2 samples; second bucket [10, 20) holds the last
    assert h2.quantile(1.0) == pytest.approx(20.0)
    assert math.isnan(Histogram("e", buckets=(1.0,)).quantile(0.5))
    with pytest.raises(ValueError, match="outside"):
        h.quantile(1.5)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError, match="ascending"):
        Histogram("bad", buckets=())


def test_registry_snapshot_and_kind_mismatch():
    reg = MetricsRegistry()
    reg.counter("serving_steps_total").inc(3)
    reg.gauge("queue_depth").set(2)
    reg.histogram("ttft_ms", buckets=(1.0, 10.0)).observe(4.0)
    reg.register_provider("tuner", lambda: {"hits": 5, "misses": 1})
    snap = reg.snapshot()
    assert snap["serving_steps_total"] == {"type": "counter", "value": 3.0}
    assert snap["queue_depth"] == {"type": "gauge", "value": 2.0}
    assert snap["ttft_ms"]["count"] == 1
    assert snap["ttft_ms"]["buckets"] == [[1.0, 0], [10.0, 1]]
    assert snap["providers"]["tuner"] == {"hits": 5, "misses": 1}
    # snake_case discipline: every key machine-parsable, no spaces/camel
    for key in snap:
        assert key == key.lower() and " " not in key, key
    assert reg.counter("serving_steps_total") is not None  # idempotent
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("serving_steps_total")


def test_registry_provider_error_captured():
    reg = MetricsRegistry()

    def boom():
        raise RuntimeError("nope")

    reg.register_provider("bad", boom)
    snap = reg.snapshot()
    assert "RuntimeError" in snap["providers"]["bad"]["error"]
    assert "bad" not in reg.prometheus_text()        # skipped, not fatal


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("steps_total").inc(2)
    reg.histogram("lat_ms", buckets=(1.0, 5.0)).observe(0.5)
    reg.register_provider("cache", lambda: {"stats": {"hits": 3},
                                            "label": "x"})
    text = reg.prometheus_text()
    assert "# TYPE steps_total counter\nsteps_total 2" in text
    assert '# TYPE lat_ms histogram' in text
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 1' in text
    assert "lat_ms_sum 0.5\nlat_ms_count 1" in text
    assert "cache_stats_hits 3" in text              # nested dict flattened
    assert "label" not in text                       # non-numeric dropped


def test_registry_export_json_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total").inc()
    path = str(tmp_path / "metrics.json")
    reg.export_json(path)
    with open(path) as f:
        assert json.load(f)["a_total"]["value"] == 1.0


# ---------------------------------------------------------------------------
# Drift detector: flags sustained slowdowns, stays silent on clean runs
# ---------------------------------------------------------------------------

def test_drift_flags_sustained_slowdown():
    det = DriftDetector(threshold=2.0, alpha=0.3, calibration=5)
    fired = []
    det.on_drift(lambda key, rep: fired.append((key, rep)))
    for _ in range(5):
        assert not det.observe("k1", 0.010, kernel="paged_decode")
    for _ in range(20):                              # sustained 5x regression
        det.observe("k1", 0.050)
    assert det.flagged() == ["k1"]
    assert len(fired) == 1                           # fires once per key
    key, rep = fired[0]
    assert key == "k1" and rep["kernel"] == "paged_decode"
    assert rep["ratio"] > 2.0
    report = det.report()
    assert report["flagged_keys"] == 1 and report["tracked_keys"] == 1
    assert report["entries"][0]["key"] == "k1"


def test_drift_silent_on_clean_run_with_compile_spike():
    det = DriftDetector(threshold=2.0, alpha=0.3, calibration=5)
    det.observe("k", 1.8)                 # first-call jit compile spike
    for _ in range(40):                   # steady state with jitter
        assert not det.observe("k", 0.004 + 0.001 * (_ % 3))
    assert det.flagged() == []


def test_drift_one_outlier_does_not_flag():
    det = DriftDetector(threshold=2.0, alpha=0.3, calibration=3)
    for _ in range(3):
        det.observe("k", 0.010)
    det.observe("k", 0.040)               # single GC pause / page fault
    for _ in range(10):
        det.observe("k", 0.010)
    assert det.flagged() == []


def test_drift_shipped_baseline_mode():
    det = DriftDetector(threshold=2.0, alpha=1.0, calibration=5,
                        use_shipped=True)
    assert not det.observe("k", 0.010, shipped=0.010)
    assert det.observe("k", 0.030, shipped=0.010)    # 3x the shipped metric
    rep = det.report()["entries"][0]
    assert rep["baseline_s"] == 0.010 and rep["shipped_metric"] == 0.010


def test_drift_validates_parameters():
    with pytest.raises(ValueError, match="threshold"):
        DriftDetector(threshold=1.0)
    with pytest.raises(ValueError, match="alpha"):
        DriftDetector(alpha=0.0)


def test_drift_export(tmp_path):
    det = DriftDetector()
    det.observe("k", 0.01, kernel="matmul")
    path = str(tmp_path / "drift.json")
    det.export(path)
    with open(path) as f:
        rep = json.load(f)
    assert rep["tracked_keys"] == 1 and rep["entries"][0]["samples"] == 1


def test_drift_active_handle():
    assert drift_lib.get_active() is None
    det = DriftDetector()
    old = drift_lib.set_active(det)
    try:
        assert drift_lib.get_active() is det
    finally:
        drift_lib.set_active(old)
    assert drift_lib.get_active() is None


# ---------------------------------------------------------------------------
# Bounded token-time recording + run-report latency summary
# ---------------------------------------------------------------------------

def test_token_times_capped_with_drop_counter():
    req = Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=1)
    for i in range(TOKEN_TIMES_CAP + 10):
        req.note_token_time(float(i))
    assert len(req.token_times) == TOKEN_TIMES_CAP
    assert req.token_times_dropped == 10
    # ITL keeps working past the cap: the last timestamp always updates
    assert req.last_token_time == float(TOKEN_TIMES_CAP + 9)


def test_latency_summary_percentiles():
    reqs = []
    for i in range(2):
        r = Request(rid=i, prompt=np.ones(2, np.int32), max_new_tokens=3)
        for t in (1.0 + i, 1.5 + i, 2.0 + i):       # ttft i+1s, itl 500ms
            r.note_token_time(t)
        reqs.append(r)
    s = latency_summary(reqs, t0=0.0)
    assert s["ttft_samples"] == 2 and s["itl_samples"] == 4
    assert s["ttft_p50_ms"] == pytest.approx(1500.0)
    assert s["itl_p50_ms"] == pytest.approx(500.0)
    assert s["token_times_dropped"] == 0
    empty = latency_summary([], t0=0.0)
    assert empty["ttft_p50_ms"] is None and empty["itl_samples"] == 0


# ---------------------------------------------------------------------------
# Scheduler + engine integration: instrumentation changes zero tokens
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="obs-t", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=128, dtype="float32")


def _seeded_reqs(rng, vocab, n=5):
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab, int(p)).astype(np.int32),
                    max_new_tokens=int(g))
            for i, (p, g) in enumerate(zip(rng.integers(2, 10, n),
                                           rng.integers(1, 5, n)))]


def test_observability_changes_zero_tokens():
    """Tokens with tracer+metrics+drift installed must be IDENTICAL to the
    uninstrumented run — observability is a read-only tap, never a
    numerics or scheduling input."""
    import jax

    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    kw = dict(num_pages=24, page_size=8, max_batch=3, max_seq_len=24,
              prefill_chunk=4)

    plain = ServingEngine(cfg, params, **kw)
    plain.run(_seeded_reqs(np.random.default_rng(3), cfg.vocab_size))
    want = {r.rid: list(r.tokens) for r in plain.scheduler.finished}

    tracer = Tracer(clock=VirtualClock())
    reg = MetricsRegistry()
    det = DriftDetector(calibration=2)
    obs = ServingEngine(cfg, params, tracer=tracer, metrics=reg,
                        drift=det, **kw)
    obs.run(_seeded_reqs(np.random.default_rng(3), cfg.vocab_size))
    got = {r.rid: list(r.tokens) for r in obs.scheduler.finished}
    assert got == want, "instrumentation changed generated tokens"

    # and the taps actually recorded the run
    names = {e["name"] for e in tracer.events}
    assert "decode" in names and "prefill" in names
    assert any(e["name"].startswith("req") for e in tracer.events)
    snap = reg.snapshot()
    assert snap["serving_steps_total"]["value"] > 0
    total = sum(len(v) for v in want.values())
    assert snap["serving_ttft_ms"]["count"] == len(want)
    assert (snap["serving_ttft_ms"]["count"]
            + snap["serving_inter_token_ms"]["count"]) == total
    assert det.entries, "drift detector saw no dispatches"
    assert "scheduler" in snap["providers"]


def test_metrics_step_counters_match_run_report():
    import jax

    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    reg = MetricsRegistry()
    engine = ServingEngine(cfg, params, num_pages=24, page_size=8,
                           max_batch=3, max_seq_len=24, prefill_chunk=4,
                           metrics=reg)
    res = engine.run(_seeded_reqs(np.random.default_rng(5),
                                  cfg.vocab_size))
    snap = reg.snapshot()
    assert snap["serving_steps_total"]["value"] == res["steps"]
    assert snap["serving_retired_total"]["value"] == res["requests"]
    assert snap["serving_decode_tokens_total"]["value"] <= \
        res["generated_tokens"]
    assert res["latency"]["ttft_samples"] == res["requests"]
    assert res["latency"]["ttft_p50_ms"] is not None


# ---------------------------------------------------------------------------
# Online retuning: drift flag -> background retune -> portfolio update ->
# re-jit with the fresh config (the serve-time half of ROADMAP item 5)
# ---------------------------------------------------------------------------

def test_drift_triggers_online_retune_end_to_end(tmp_path):
    """Serve with a forced slowdown on paged_decode and walk the whole
    online-retuning loop: the detector flags the dispatch key, the engine
    re-enqueues it through the default tuner, the flushed background tune
    admits the fresh winner into the live portfolio, and the NEXT run
    re-jits onto it and resets the detector — with the drift counters
    visible in both the run report and the metrics registry."""
    import jax

    from repro.core import get_chip
    from repro.core import tuner as tuner_mod
    from repro.core.cache import TuningCache
    from repro.core.measure import AnalyticalMeasure
    from repro.core.portfolio import PORTFOLIO_SCHEMA, Portfolio
    from repro.core.tuner import Autotuner
    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine
    from repro.serving import faults as fault_lib

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    kw = dict(num_pages=24, page_size=8, max_batch=3, max_seq_len=24,
              prefill_chunk=4)

    pf = Portfolio({"schema": PORTFOLIO_SCHEMA, "threshold": 0.1,
                    "max_members": 8, "source_entries": 0, "kernels": {}})
    tuner = Autotuner(cache=TuningCache(cache_dir=str(tmp_path / "dt")),
                      backend=AnalyticalMeasure(get_chip("tpu_v5e")),
                      on_miss="heuristic", portfolio=pf,
                      config_source="db")
    tuner_mod.set_default_tuner(tuner)
    try:
        det = DriftDetector(threshold=3.0, alpha=0.3, calibration=4)
        reg = MetricsRegistry()
        eng = ServingEngine(cfg, params, drift=det, metrics=reg, **kw)

        # Run 1 (clean): calibrates the decode key's baseline. No flags.
        res1 = eng.run(_seeded_reqs(np.random.default_rng(11),
                                    cfg.vocab_size))
        d1 = res1["drift"]
        assert d1["tracked_keys"] >= 1 and d1["flagged"] == 0

        # Run 2 (200ms injected into every paged_decode launch, inside
        # the dispatch-timing window): sustained regression -> flag ->
        # synchronous on_drift -> retune enqueued, awaiting the daemon.
        plan = fault_lib.FaultPlan.parse_spec("slow@64:200:paged_decode")
        with fault_lib.active(plan):
            res2 = eng.run(_seeded_reqs(np.random.default_rng(12),
                                        cfg.vocab_size))
        d2 = res2["drift"]
        assert d2["flagged"] >= 1 and d2["retunes"] >= 1
        assert d2["pending_retunes"] >= 1 and d2["flagged_keys"] >= 1
        assert any(l["fault"] == "slowdown" for l in plan.log)
        assert tuner.stats()["drift_retunes"] >= 1
        assert len(tuner.queue) >= 1

        # The background daemon (flushed inline for determinism) retunes
        # the drifted scenario and admits the winner into the portfolio.
        assert tuner.flush_tuning_queue() >= 1
        st = tuner.stats()
        assert st["tunes"] >= 1 and st["portfolio_updates"] >= 1
        assert pf.counts()["members"] >= 1

        # Run 3 (clean): the engine notices the fresher cache entry,
        # re-jits once, clears the pending set, and resets the detector
        # key so the new config calibrates its own baseline.
        res3 = eng.run(_seeded_reqs(np.random.default_rng(13),
                                    cfg.vocab_size))
        d3 = res3["drift"]
        assert d3["rejits"] >= 1
        assert d3["pending_retunes"] == 0 and d3["flagged_keys"] == 0

        # Subsequent dispatches serve the freshly tuned winner — and the
        # live portfolio's selector tracks the same config.
        ctx, used = tuner.last_dispatch("paged_decode")
        from repro.kernels.registry import get_kernel
        kernel = get_kernel("paged_decode").tunable
        entry = tuner.cache.get_raw(kernel.name, kernel.version,
                                    kernel.space, ctx)
        assert entry is not None and used == entry.config
        assert pf.select(kernel, ctx) == entry.config

        # Measured-vs-shipped drift counters surface in the registry too.
        prov = reg.snapshot()["providers"]["drift"]
        assert prov["flagged"] >= 1 and prov["retunes"] >= 1
        assert prov["rejits"] >= 1
    finally:
        tuner_mod.set_default_tuner(None)
