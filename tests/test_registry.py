"""Kernel-registry behavior: registration, scenario lookup, duplicate
rejection, and the declarative completeness of every built-in KernelSpec."""

import pytest

from repro.core import Param, ConfigSpace, TunableKernel, get_chip
from repro.kernels import registry as reg


def _dummy_spec(name="_test_dummy", scenarios=("decode",)):
    space = ConfigSpace(name, [Param("block", (8, 16))])
    return reg.KernelSpec(
        tunable=TunableKernel(name=name, space=space,
                              heuristic=lambda ctx: {"block": 8}),
        scenarios=tuple(scenarios))


# ---------------------------------------------------------------------------
# registration / lookup
# ---------------------------------------------------------------------------

def test_builtin_kernels_registered():
    names = reg.kernel_names()
    for expected in ("flash_attention", "flash_attention_bwd",
                     "decode_attention", "gqa_decode_ragged", "mla_decode",
                     "rms_norm", "matmul"):
        assert expected in names


def test_scenario_lookup_decode_family():
    decode = reg.kernel_names(scenario="decode")
    assert len(decode) >= 3
    assert {"decode_attention", "gqa_decode_ragged", "mla_decode"} <= \
        set(decode)
    assert reg.kernel_names(scenario="mla") == ["mla_decode"]
    assert "flash_attention" in reg.kernel_names(scenario="prefill")
    assert "flash_attention" not in decode


def test_get_kernel_roundtrip_and_unknown():
    spec = reg.get_kernel("mla_decode")
    assert spec.name == "mla_decode"
    assert spec.tunable.name == "mla_decode"
    with pytest.raises(KeyError, match="no kernel 'nope'"):
        reg.get_kernel("nope")


def test_register_and_unregister():
    spec = _dummy_spec()
    try:
        reg.register(spec)
        assert reg.get_kernel(spec.name) is spec
        assert spec.name in reg.kernel_names(scenario="decode")
    finally:
        reg.unregister(spec.name)
    assert spec.name not in reg.kernel_names()


def test_duplicate_name_rejected():
    spec = _dummy_spec()
    reg.register(spec)
    try:
        with pytest.raises(ValueError, match="already registered"):
            reg.register(_dummy_spec())
        with pytest.raises(ValueError, match="already registered"):
            reg.register(spec)      # even the same object
    finally:
        reg.unregister(spec.name)


def test_register_requires_scenarios_and_spec_type():
    with pytest.raises(ValueError, match="no scenarios"):
        reg.register(_dummy_spec(scenarios=()))
    with pytest.raises(TypeError):
        reg.register("flash_attention")


# ---------------------------------------------------------------------------
# declarative completeness of the built-ins
# ---------------------------------------------------------------------------

def test_every_spec_heuristic_is_valid_for_its_bench_cases():
    chip = get_chip("tpu_v5e")
    for spec in reg.list_kernels():
        assert spec.bench_cases, f"{spec.name} declares no bench cases"
        for case in spec.bench_cases:
            ctx = case.context(chip)
            cfg = spec.tunable.default_config(ctx)
            assert spec.space.is_valid(cfg, ctx), \
                f"{spec.name}/{case.label}: default {cfg} invalid"


def test_every_decode_spec_has_oracle_and_entry_point():
    for spec in reg.list_kernels(scenario="decode"):
        assert spec.reference is not None, spec.name
        assert spec.entry_point is not None, spec.name


def test_bench_case_scale_filter():
    spec = reg.get_kernel("flash_attention")
    host = spec.cases(scale="host")
    paper = spec.cases(scale="paper")
    assert host and paper
    assert len(host) + len(paper) == len(spec.bench_cases)


# ---------------------------------------------------------------------------
# registry-driven tuner construction
# ---------------------------------------------------------------------------

def test_tuner_accepts_registry_names(tuner):
    chip = get_chip("tpu_v5e")
    ctx = reg.get_kernel("mla_decode").cases(scale="paper")[0].context(chip)
    by_name = tuner.best_config("mla_decode", ctx)
    by_obj = tuner.best_config(reg.get_kernel("mla_decode").tunable, ctx)
    assert by_name == by_obj

    gspec = reg.get_kernel("gqa_decode_ragged")
    gctx = gspec.cases(scale="paper")[0].context(chip)
    entry = tuner.tune("gqa_decode_ragged", gctx)
    assert gspec.space.is_valid(entry.config, gctx)
