"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles, plus
hypothesis properties on kernel math invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.rms_norm import rms_norm


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Hq, Hkv, Sq, Skv, D, causal, window, bq, bk
    (2, 4, 2, 256, 256, 64, True, None, 64, 128),
    (1, 8, 8, 128, 128, 128, True, None, 128, 128),    # MHA
    (2, 6, 2, 200, 200, 96, True, None, 64, 128),      # ragged + GQA3 + D96
    (1, 4, 1, 256, 256, 128, True, 64, 64, 128),       # sliding window
    (1, 2, 2, 64, 512, 128, False, None, 64, 256),     # cross attention
    (1, 4, 2, 320, 320, 160, True, None, 64, 128),     # stablelm head_dim
    (1, 32, 32, 128, 128, 96, True, None, 128, 128),   # phi3-like MHA D96
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Hq, Hkv, Sq, Skv, D, causal, window, bq, bk = case
    q = rand(0, (B, Hq, Sq, D), dtype)
    k = rand(1, (B, Hkv, Skv, D), dtype)
    v = rand(2, (B, Hkv, Skv, D), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=bq, block_kv=bk)
    oref = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol(dtype))


def test_flash_attention_lse():
    q, k, v = (rand(i, (1, 2, 128, 64), jnp.float32) for i in range(3))
    o, lse = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                             return_lse=True)
    _, lse_ref = ref.attention(q, k, v, causal=True, return_lse=True)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=1e-4)


def test_flash_block_config_does_not_change_result():
    """The paper's core premise: configs change speed, never semantics."""
    q, k, v = (rand(i, (1, 4, 256, 64), jnp.float32) for i in range(3))
    outs = [flash_attention(q, k, v, block_q=bq, block_kv=bk)
            for bq, bk in [(64, 128), (128, 128), (256, 256), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    # B, Hq, Hkv, T, D, block_kv, k_splits
    (2, 4, 2, 512, 64, 128, 2),
    (1, 8, 8, 300, 128, 128, 4),     # MHA, ragged T
    (3, 6, 2, 1024, 128, 256, 1),
    (1, 16, 2, 2048, 64, 512, 8),    # deep GQA, many splits
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(case, dtype):
    B, Hq, Hkv, T, D, bk, ks = case
    q = rand(0, (B, Hq, D), dtype)
    k = rand(1, (B, Hkv, T, D), dtype)
    v = rand(2, (B, Hkv, T, D), dtype)
    lens = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, T + 1)
    o = decode_attention(q, k, v, kv_len=lens, block_kv=bk, k_splits=ks)
    oref = ref.decode_attention(q, k, v, kv_len=lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol(dtype))


def test_decode_ragged_lengths_mask_tail():
    """Keys beyond kv_len must not influence the output."""
    B, Hq, Hkv, T, D = 2, 4, 2, 256, 64
    q = rand(0, (B, Hq, D), jnp.float32)
    k = rand(1, (B, Hkv, T, D), jnp.float32)
    v = rand(2, (B, Hkv, T, D), jnp.float32)
    lens = jnp.array([100, 17], jnp.int32)
    o1 = decode_attention(q, k, v, kv_len=lens, block_kv=128, k_splits=2)
    k2 = k.at[:, :, 200:].set(99.0)     # garbage in the masked tail
    v2 = v.at[:, :, 200:].set(-99.0)
    o2 = decode_attention(q, k2, v2, kv_len=lens, block_kv=128, k_splits=2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# ---------------------------------------------------------------------------
# rms norm + matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,block", [((256, 1024), 64),
                                         ((100, 3072), 128),
                                         ((512, 512), 8),
                                         ((33, 160), 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_vs_ref(shape, block, dtype):
    x = rand(0, shape, dtype)
    w = rand(1, (shape[-1],), dtype)
    o = rms_norm(x, w, block_rows=block)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref.rms_norm(x, w), np.float32),
                               atol=tol(dtype))


@pytest.mark.parametrize("mnk,blocks", [((256, 512, 256), (128, 128, 256)),
                                        ((200, 300, 100), (128, 128, 128)),
                                        ((64, 64, 64), (128, 128, 128))])
def test_matmul_vs_ref(mnk, blocks):
    M, K, N = mnk
    bm, bn, bk = blocks
    x = rand(0, (M, K), jnp.float32)
    y = rand(1, (K, N), jnp.float32)
    o = matmul(x, y, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul(x, y)),
                               atol=2e-4)


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

@given(st.integers(1, 3), st.integers(1, 4), st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_rms_norm_scale_invariance(b, blk_pow, c):
    """rms_norm(c·x) == rms_norm(x) for c > 0 (degree-0 homogeneity)."""
    x = rand(b, (32, 128), jnp.float32)
    w = jnp.ones((128,), jnp.float32)
    o1 = rms_norm(x, w, block_rows=8 * 2 ** blk_pow)
    o2 = rms_norm(x * c, w, block_rows=8 * 2 ** blk_pow)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-3)


@given(st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_attention_softmax_shift_invariance(seed):
    """attention(q, k, v) is invariant to adding a constant to all scores
    (softmax shift) — uniform scaling of q must equal temperature change,
    and duplicate keys must average their values."""
    q = rand(seed, (1, 2, 64, 32), jnp.float32)
    k = rand(seed + 1, (1, 2, 64, 32), jnp.float32)
    v = rand(seed + 2, (1, 2, 64, 32), jnp.float32)
    # duplicate every key/value: output must be identical (weights halve)
    k2 = jnp.concatenate([k, k], axis=2)
    v2 = jnp.concatenate([v, v], axis=2)
    o1 = flash_attention(q, k, v, causal=False, block_q=64, block_kv=64)
    o2 = flash_attention(q, k2, v2, causal=False, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@given(st.sampled_from([64, 128, 256]), st.sampled_from([128, 256]))
@settings(max_examples=6, deadline=None)
def test_matmul_blocks_semantics_free(bm, bk):
    x = rand(0, (128, 256), jnp.float32)
    y = rand(1, (256, 128), jnp.float32)
    o = matmul(x, y, block_m=bm, block_n=128, block_k=bk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(x @ y), atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention backward kernels
# ---------------------------------------------------------------------------

BWD_CASES = [
    # B, Hq, Hkv, Sq, Skv, D, causal, window, bq, bk
    (1, 4, 2, 128, 128, 64, True, None, 64, 128),
    (2, 2, 2, 200, 200, 64, True, None, 64, 128),
    (1, 6, 2, 128, 128, 64, True, 48, 64, 128),
    (1, 2, 1, 64, 256, 64, False, None, 64, 128),
]


@pytest.mark.parametrize("case", BWD_CASES)
def test_flash_attention_bwd_vs_autodiff(case):
    from repro.kernels.flash_attention_bwd import flash_attention_bwd
    B, Hq, Hkv, Sq, Skv, D, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D))
    do = jax.random.normal(ks[3], (B, Hq, Sq, D))
    o, lse = flash_attention(q, k, v, causal=causal, window=window,
                             block_q=bq, block_kv=bk, return_lse=True)
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     window=window, block_q=bq, block_kv=bk)
    gq, gk, gv = jax.grad(
        lambda q_, k_, v_: jnp.sum(ref.attention(
            q_, k_, v_, causal=causal, window=window) * do),
        argnums=(0, 1, 2))(q, k, v)
    for got, want in ((dq, gq), (dk, gk), (dv, gv)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-4)


def test_flash_bwd_block_config_semantics_free():
    from repro.kernels.flash_attention_bwd import flash_attention_bwd
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    do = jax.random.normal(ks[3], (1, 2, 256, 64))
    o, lse = flash_attention(q, k, v, return_lse=True, block_q=64,
                             block_kv=128)
    base = flash_attention_bwd(q, k, v, o, lse, do, block_q=64, block_kv=128)
    for bq, bk in [(128, 128), (256, 256), (64, 256)]:
        out = flash_attention_bwd(q, k, v, o, lse, do, block_q=bq,
                                  block_kv=bk)
        for a, b in zip(out, base):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
