"""Golden-log regression for the four search strategies.

The ask/tell protocol guarantees trial logs are order-deterministic and
batch-size-invariant; tests/test_engine.py checks self-consistency within
one build of the code. This suite pins the logs against COMMITTED
fixtures, so an ask/tell refactor that silently reorders trials (same
final winner, different exploration order) fails at PR time instead of
invalidating every historical search-efficiency comparison.

Regenerate fixtures after an INTENTIONAL ordering change with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_search_golden.py
"""

import json
import os

import pytest

from repro.core import (
    ConfigSpace, EvolutionarySearch, ExhaustiveSearch, Param, RandomSearch,
    SuccessiveHalving, Trial, TuningContext, get_chip,
)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "search_golden")

STRATEGIES = {
    "exhaustive": lambda: ExhaustiveSearch(),
    "random": lambda: RandomSearch(budget=12, seed=3),
    "evolutionary": lambda: EvolutionarySearch(population=4, generations=3,
                                               children=4, seed=5),
    "successive_halving": lambda: SuccessiveHalving(initial=10, rungs=3,
                                                    base_fidelity=1,
                                                    fidelity_mult=4, seed=7),
}


def _space():
    sp = ConfigSpace("golden", [Param("block", (32, 64, 128, 256, 512)),
                                Param("splits", (1, 2, 4, 8))])
    sp.constrain("splits<=block/16",
                 lambda c, x: c["splits"] <= c["block"] // 16)
    return sp


def _ctx():
    return TuningContext(chip=get_chip("tpu_v5e"), shapes={"x": (1024, 1024)})


def _evaluate(cfg, fidelity=1):
    """Deterministic synthetic landscape (pure integer/float arithmetic —
    bit-identical across platforms): a bowl around (128, 4) whose noise
    term shrinks with fidelity, exercising the SH rung logs."""
    base = abs(cfg["block"] - 128) / 64.0 + abs(cfg["splits"] - 4) * 0.25
    noise = ((cfg["block"] * 31 + cfg["splits"] * 17) % 7) / (10.0 * fidelity)
    return 0.1 + base + noise


def _serialize(trials):
    return json.dumps(
        [{"config": {k: t.config[k] for k in sorted(t.config)},
          "metric": t.metric, "fidelity": t.fidelity} for t in trials],
        indent=1, sort_keys=True).encode() + b"\n"


def _log_via_run(strategy):
    return strategy.run(_space(), _ctx(), _evaluate).trials


def _log_via_ask_tell(strategy, batch):
    strategy.reset(_space(), _ctx())
    while not strategy.finished():
        configs = strategy.suggest(batch)
        if not configs:
            break
        fid = strategy.fidelity
        strategy.observe([Trial(dict(c), _evaluate(c, fidelity=fid),
                                fidelity=fid) for c in configs])
    return strategy.result().trials


def _fixture_path(name):
    return os.path.join(FIXTURES, f"{name}.json")


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_trial_log_matches_committed_fixture(name):
    got = _serialize(_log_via_run(STRATEGIES[name]()))
    path = _fixture_path(name)
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        os.makedirs(FIXTURES, exist_ok=True)
        with open(path, "wb") as f:
            f.write(got)
        pytest.skip(f"regenerated {path}")
    with open(path, "rb") as f:
        want = f.read()
    assert got == want, (
        f"{name}: trial log diverged from the committed fixture. If the "
        f"ordering change is intentional, regenerate with "
        f"REPRO_REGEN_GOLDEN=1 (see module docstring).")


@pytest.mark.parametrize("batch", [1, 3, 7])
@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_ask_tell_batches_reproduce_fixture(name, batch):
    """Driving suggest/observe at any batch size must produce the SAME
    byte-identical log as the committed serial fixture — the engine can
    pipeline at any width without changing what history records."""
    path = _fixture_path(name)
    if not os.path.exists(path):
        pytest.skip("fixtures not generated yet")
    got = _serialize(_log_via_ask_tell(STRATEGIES[name](), batch))
    with open(path, "rb") as f:
        want = f.read()
    assert got == want


def test_fixture_logs_nonempty_and_valid():
    sp, ctx = _space(), _ctx()
    for name in STRATEGIES:
        path = _fixture_path(name)
        if not os.path.exists(path):
            pytest.skip("fixtures not generated yet")
        trials = json.loads(open(path).read())
        assert len(trials) >= 5, (name, len(trials))
        for t in trials:
            assert sp.is_valid(t["config"], ctx), (name, t)
