"""End-to-end system behaviour: tiny training run converges; serving
generates; the whole public API is importable."""

import jax
import jax.numpy as jnp


def test_public_api_imports():
    import repro
    import repro.core
    import repro.kernels
    import repro.models
    import repro.configs
    import repro.distribution
    from repro.launch import hlo_analysis, mesh, steps  # noqa: F401
    assert repro.__version__


def test_tiny_training_loss_decreases(tmp_path):
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, TokenStream
    from repro.launch import steps as S
    from repro.launch.mesh import make_local_mesh
    from repro.models import lm
    from repro.models.param import init_params
    from repro.optim import adamw

    cfg = get_config("phi4-mini-3.8b", smoke=True)
    mesh = make_local_mesh()
    scfg = S.StepConfig(adamw=adamw.AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=40, schedule="constant"),
        opts=lm.ForwardOpts(attn_chunk=64))
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    opt = S.init_opt_state(cfg, scfg, params)
    step = jax.jit(S.make_train_step(cfg, scfg, mesh))
    stream = iter(TokenStream(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=64, global_batch=4)))
    losses = []
    for _ in range(25):
        batch = next(stream)
        params, opt, m = step(params, opt,
                              jax.tree.map(jnp.asarray, batch))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_generation_roundtrip():
    from repro.configs import get_config
    from repro.launch import steps as S
    from repro.launch.mesh import make_local_mesh
    from repro.models import lm
    from repro.models.param import init_params

    cfg = get_config("mamba2-2.7b", smoke=True)     # SSM decode path
    mesh = make_local_mesh()
    scfg = S.StepConfig(policy="serve_tp", opts=lm.ForwardOpts(attn_chunk=32))
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    prefill = jax.jit(S.make_prefill_step(cfg, scfg, mesh, max_len=20))
    decode = jax.jit(S.make_decode_step(cfg, scfg, mesh))
    logits, cache = prefill(params, toks)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(4):
        logits, cache = decode(params, tok, cache, jnp.int32(12 + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert tok.shape == (2, 1)
        assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))
