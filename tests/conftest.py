import os
import subprocess
import sys
import tempfile

import pytest

# Tests run on the single host CPU device (the dry-run sets its own flags in
# a separate process). Keep kernels in interpret mode and tuning caches in
# tmp dirs so tests never touch the user cache.
os.environ.setdefault("REPRO_TARGET_CHIP", "tpu_v5e")

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def hypothesis_fallback():
    """Stand-ins for (given, settings, st) when hypothesis is not installed:
    property tests become skipped placeholders instead of collection errors,
    so the rest of each module still runs."""

    class _Anything:
        """Absorbs any strategy-building call chain (st.composite, st.lists
        of st.tuples, draw(...), ...) — never executed, only decorated."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Anything()

    def given(*a, **k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def placeholder():
                pass
            placeholder.__name__ = getattr(fn, "__name__", "property_test")
            placeholder.__doc__ = fn.__doc__
            return placeholder
        return deco

    def settings(*a, **k):
        return lambda fn: fn

    return given, settings, _Strategies()


@pytest.fixture()
def tmp_cache(tmp_path):
    from repro.core.cache import TuningCache
    return TuningCache(cache_dir=str(tmp_path / "tuning"))


@pytest.fixture()
def tuner(tmp_cache):
    from repro.core import Autotuner, AnalyticalMeasure, get_chip
    return Autotuner(cache=tmp_cache,
                     backend=AnalyticalMeasure(get_chip("tpu_v5e")))


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 300) -> str:
    """Run python code in a fresh process with N forced host devices —
    multi-device tests can't share the main test process (jax locks the
    device count on first init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
