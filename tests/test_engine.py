"""Pipelined tuning engine: ask/tell protocol, compile/measure split,
dedupe, background tuning, and the concurrency surface."""

import math
import threading
import time

import pytest

from repro.core import (
    AnalyticalMeasure, Autotuner, ConfigSpace, ExhaustiveSearch,
    HybridMeasure, KernelRunner, KernelWorkload, Param, RandomSearch,
    SuccessiveHalving, EvolutionarySearch, TunableKernel, Trial,
    TuningContext, WallClockTimer, get_chip, make_strategy,
)
from repro.core.costmodel import estimate_seconds
from repro.core.engine import TuningEngine
from repro.core.measure import CompilePool


def space():
    return ConfigSpace("e", [Param("a", (1, 2, 4, 8, 16)),
                             Param("b", (1, 2, 4, 8))])


def ctx():
    return TuningContext(chip=get_chip("tpu_v5e"), shapes={"x": (64, 128)})


def bowl(cfg, fidelity=1):
    return (cfg["a"] - 4) ** 2 + (cfg["b"] - 2) ** 2 + 0.1


def drive_ask_tell(strat, sp, c, evaluate, batch: int):
    """Hand-rolled ask/tell loop at an arbitrary batch size."""
    strat.reset(sp, c)
    while not strat.finished():
        cfgs = strat.suggest(batch)
        if not cfgs:
            break
        fid = strat.fidelity
        strat.observe([Trial(dict(cfg), evaluate(cfg, fidelity=fid),
                             fidelity=fid) for cfg in cfgs])
    return strat.result()


ALL_STRATEGIES = ["exhaustive", "random", "evolutionary",
                  "successive_halving"]


@pytest.mark.parametrize("name", ALL_STRATEGIES)
@pytest.mark.parametrize("batch", [1, 3, 7])
def test_ask_tell_matches_serial_run(name, batch):
    """Same seed => byte-identical trial logs and best config, for every
    strategy and any in-flight batch size."""
    kwargs = {"budget": 10} if name == "random" else {}
    a = make_strategy(name, **kwargs).run(space(), ctx(), bowl)
    b = drive_ask_tell(make_strategy(name, **kwargs), space(), ctx(), bowl,
                       batch)
    assert a.best == b.best
    assert a.best_metric == b.best_metric
    assert a.trials == b.trials          # byte-identical log


def test_ask_tell_idle_suggest_is_empty():
    s = make_strategy("exhaustive")
    s.reset(space(), ctx())
    got = s.suggest(1000)
    assert len(got) == 20
    assert s.suggest(1) == []            # everything outstanding
    s.observe([Trial(c, bowl(c)) for c in got])
    assert s.finished()


def test_successive_halving_falls_back_to_earlier_rung():
    """If every highest-fidelity measurement fails, the best finite trial
    from an earlier rung wins instead of reporting total failure."""

    def flaky_high_fidelity(cfg, fidelity=1):
        if fidelity > 1:
            return math.inf
        return bowl(cfg)

    res = SuccessiveHalving(initial=12, rungs=3, base_fidelity=1,
                            fidelity_mult=4).run(space(), ctx(),
                                                 flaky_high_fidelity)
    assert res.best is not None
    assert math.isfinite(res.best_metric)
    assert res.best_metric == min(t.metric for t in res.trials if t.ok())


def test_valid_configs_enumeration_is_cached():
    sp = space()
    calls = {"n": 0}

    def counting(cfg, c):
        calls["n"] += 1
        return True

    sp.constrain("count", counting)
    c = ctx()
    first = sp.valid_configs(c)
    n_after_first = calls["n"]
    again = sp.valid_configs(c)
    assert calls["n"] == n_after_first   # second enumeration: pure cache hit
    assert first == again
    # Returned lists are private copies — caller mutation can't poison it.
    again[0]["a"] = 999
    assert sp.valid_configs(c)[0]["a"] != 999


# ---------------------------------------------------------------------------
# Autotuner: failed entries, background worker, tune_many
# ---------------------------------------------------------------------------

def _kernel(name="e", workload=None):
    def wl(cfg, c):
        return KernelWorkload(flops=1e9, hbm_bytes=1e8 / cfg["a"],
                              grid_steps=64 // cfg["a"], vmem_bytes=1024)
    return TunableKernel(name, space(), workload_fn=workload or wl,
                         heuristic=lambda c: {"a": 1, "b": 1})


def test_inf_cache_entry_is_never_a_hit(tmp_cache):
    """A persisted failed search must not be served; the tuner retunes."""
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")))

    def bad(cfg, c):
        raise RuntimeError("boom")

    entry = t.tune(_kernel(workload=bad), ctx())
    assert math.isinf(entry.metric)      # failure recorded for visibility
    # A healthy kernel under the same cache key now tunes instead of
    # reusing the poisoned entry.
    cfg = t.best_config(_kernel(), ctx())
    assert t.stats()["misses"] == 1 and t.stats()["tunes"] == 2
    assert t.stats()["failed_retunes"] == 1
    assert cfg["a"] == 16                # true optimum, not the inf config
    # The cache-level filter agrees with the tuner-level policy.
    assert t.cache.get("e", 1, space(), ctx(), skip_failed=True) is not None
    assert t.best_config(_kernel(), ctx()) == cfg
    assert t.stats()["hits"] == 1          # finite entry is a normal hit


def test_inf_entry_reenqueues_under_heuristic(tmp_cache):
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")),
                  on_miss="heuristic")

    def bad(cfg, c):
        raise RuntimeError("boom")

    t.tune(_kernel(workload=bad), ctx())
    cfg = t.best_config(_kernel(), ctx())
    assert cfg == {"a": 1, "b": 1}       # heuristic, not the inf entry
    assert len(t.queue) == 1             # re-enqueued for background tuning


def test_background_worker_drains_queue(tmp_cache):
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")),
                  on_miss="heuristic")
    t.start_background_tuning(poll_interval_s=0.01)
    try:
        cfg = t.best_config(_kernel(), ctx())
        assert cfg == {"a": 1, "b": 1}   # instant heuristic on the hot path
        deadline = time.monotonic() + 30
        while t.stats()["background_tunes"] < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert t.stats()["background_tunes"] >= 1
        assert len(t.queue) == 0
        assert t.best_config(_kernel(), ctx()) == {"a": 16, "b": 1}
    finally:
        t.stop_background_tuning()


def test_start_background_tuning_is_idempotent(tmp_cache):
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")))
    th1 = t.start_background_tuning(poll_interval_s=0.01)
    th2 = t.start_background_tuning(poll_interval_s=0.01)
    assert th1 is th2
    t.stop_background_tuning()


def test_tune_many_parallel_cache_writes(tmp_cache):
    """Concurrent tune_many workers persist every entry race-free."""
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")))
    ctxs = [TuningContext(chip=get_chip("tpu_v5e"), shapes={"x": (64 * i, 128)})
            for i in range(1, 9)]
    entries = t.tune_many([(_kernel(), c) for c in ctxs], max_workers=4)
    assert len(entries) == 8
    assert all(math.isfinite(e.metric) for e in entries)
    assert len(t.cache) == 8             # one persisted entry per context
    for c in ctxs:
        assert t.best_config(_kernel(), c) == {"a": 16, "b": 1}
    assert t.stats()["hits"] == 8


def test_tune_many_return_exceptions(tmp_cache):
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")))
    no_workload = TunableKernel("nw", space())    # analytical can't measure
    out = t.tune_many([(_kernel(), ctx()), (no_workload, ctx())],
                      return_exceptions=True)
    assert math.isfinite(out[0].metric)
    assert isinstance(out[1], Exception)
    with pytest.raises(ValueError):
        t.tune_many([(no_workload, ctx())])


@pytest.mark.parametrize("kernel", ["paged_decode", "matmul_w8a8",
                                    "gqa_decode_kv8"])
@pytest.mark.parametrize("name", ALL_STRATEGIES)
def test_registry_kernel_ask_tell_determinism(name, kernel):
    """PR-2's ask/tell contract on the serving/quant kernels: the same
    seed must produce byte-identical trial logs at any in-flight batch
    size (engine.run() == hand-driven batches). The quant kernels' spaces
    flow through the pipelined engine unchanged — their extra tunables
    (dequant placement, scale granularity) are just more dimensions."""
    from repro.kernels.registry import get_kernel

    spec = get_kernel(kernel)
    chip = get_chip("tpu_v5e")
    c = spec.cases(scale="host")[0].context(chip)
    ev = AnalyticalMeasure(chip).evaluator(spec.tunable, c)
    kwargs = {"budget": 12} if name == "random" else {}
    a = make_strategy(name, **kwargs).run(spec.space, c, ev)
    assert a.best is not None
    for batch in (2, 5):
        b = drive_ask_tell(make_strategy(name, **kwargs), spec.space, c,
                           ev, batch)
        assert a.best == b.best
        assert a.best_metric == b.best_metric
        assert a.trials == b.trials      # byte-identical log


def test_stats_per_kernel_hit_miss_counts(tmp_cache):
    """tuner.stats() exposes per-kernel cache-hit/miss/tune counters (the
    serving benchmark reports tuning amortization from these)."""
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")))
    k1, k2 = _kernel("k1"), _kernel("k2")
    t.best_config(k1, ctx())                       # miss -> tune
    t.best_config(k1, ctx())                       # hit
    t.best_config(k2, ctx())                       # miss -> tune
    s = t.stats()
    assert s["hits"] == 1 and s["misses"] == 2 and s["tunes"] == 2
    assert s["per_kernel"]["k1"] == {"hits": 1, "misses": 1, "tunes": 1,
                                     "background_tunes": 0}
    assert s["per_kernel"]["k2"]["misses"] == 1
    assert s["per_kernel"]["k2"]["hits"] == 0
    # Snapshot semantics: mutating the returned dict can't poison counters.
    s["per_kernel"]["k1"]["hits"] = 99
    assert t.stats()["per_kernel"]["k1"]["hits"] == 1
    # tune_many records per-kernel tunes too (batch warm-start path).
    ctxs = [TuningContext(chip=get_chip("tpu_v5e"),
                          shapes={"x": (64 * i, 128)}) for i in (2, 3)]
    t.tune_many([(k1, c) for c in ctxs])
    assert t.stats()["per_kernel"]["k1"]["tunes"] == 3


def test_stats_background_tunes_per_kernel(tmp_cache):
    t = Autotuner(cache=tmp_cache,
                  backend=AnalyticalMeasure(get_chip("tpu_v5e")),
                  on_miss="heuristic")
    t.start_background_tuning(poll_interval_s=0.01)
    try:
        t.best_config(_kernel("bgk"), ctx())
        deadline = time.monotonic() + 30
        while (t.stats()["background_tunes"] < 1
               and time.monotonic() < deadline):
            time.sleep(0.01)
        s = t.stats()
        assert s["per_kernel"]["bgk"]["background_tunes"] == 1
        assert s["per_kernel"]["bgk"]["misses"] == 1
    finally:
        t.stop_background_tuning()


# ---------------------------------------------------------------------------
# HybridMeasure fidelity switchover
# ---------------------------------------------------------------------------

def test_hybrid_measure_fidelity_switchover():
    chip = get_chip("tpu_v5e")
    timed = {"n": 0}

    def runner_factory(cfg, c):
        def run():
            timed["n"] += 1
            return 0
        return run

    k = _kernel()
    k = TunableKernel("h", space(), workload_fn=k.workload_fn,
                      make_runner=runner_factory)
    hybrid = HybridMeasure(chip, timer=WallClockTimer(reps=1, warmup=0),
                           wall_clock_fidelity=4)
    ev = hybrid.evaluator(k, ctx())
    cfg = {"a": 4, "b": 2}
    low = ev(cfg, fidelity=1)
    assert timed["n"] == 0               # below threshold: model only
    assert low == pytest.approx(
        estimate_seconds(k.workload_fn(cfg, ctx()), chip))
    high = ev(cfg, fidelity=4)
    assert timed["n"] >= 1               # threshold reached: real timing
    assert high != low


def test_hybrid_without_runner_stays_analytical():
    chip = get_chip("tpu_v5e")
    hybrid = HybridMeasure(chip, wall_clock_fidelity=4)
    ev = hybrid.evaluator(_kernel(), ctx())
    assert ev({"a": 4, "b": 2}, fidelity=8) == ev({"a": 4, "b": 2},
                                                  fidelity=1)


# ---------------------------------------------------------------------------
# CompilePool + engine on real (tiny) kernels
# ---------------------------------------------------------------------------

import jax
import jax.numpy as jnp


def _jit_kernel(shared_program: bool):
    """A wall-clock-tunable toy kernel. With ``shared_program`` every config
    lowers to the identical HLO (the 'A Few Fit Most' extreme)."""
    sp = ConfigSpace("jit", [Param("k", (1, 2, 3))])

    def make_runner(cfg, c):
        k = 1 if shared_program else cfg["k"]
        fn = jax.jit(lambda x: x * float(k) + 1.0)
        return KernelRunner(fn, jnp.ones((8, 128), jnp.float32))

    return TunableKernel("jit", sp, make_runner=make_runner)


def test_compile_pool_dedupes_identical_lowerings():
    pool = CompilePool(workers=1)
    k = _jit_kernel(shared_program=True)
    p1 = pool.begin(k.make_runner({"k": 1}, ctx()), {"k": 1})
    p2 = pool.begin(k.make_runner({"k": 2}, ctx()), {"k": 2})
    assert p1.hlo_hash == p2.hlo_hash
    assert p1.owns_compile and not p2.owns_compile
    r1, r2 = pool.finish(p1), pool.finish(p2)
    assert not r1.deduped and r2.deduped
    assert r2.compile_s == 0.0           # charged once, to the owner
    assert pool.distinct_programs == 1
    m1, _ = WallClockTimer(reps=1, warmup=1).time_prepared(r1)
    assert math.isfinite(m1)
    pool.close()


def test_engine_dedupes_metrics_and_accounts_time():
    engine = TuningEngine(WallClockTimer(reps=1, warmup=1))
    k = _jit_kernel(shared_program=True)
    res = engine.search(k, ctx(), ExhaustiveSearch())
    assert len(res.trials) == 3
    measured = [t for t in res.trials if not t.deduped]
    assert len(measured) == 1            # one program timed once
    assert all(t.metric == measured[0].metric for t in res.trials)
    assert measured[0].compile_s > 0
    assert measured[0].measure_s > 0
    engine.close()


def test_engine_matches_serial_exploration_wall_clock():
    k = _jit_kernel(shared_program=False)
    timer = WallClockTimer(reps=1, warmup=1)
    serial = ExhaustiveSearch().run(k.space, ctx(),
                                    timer.evaluator(k, ctx()))
    engine = TuningEngine(timer)
    piped = engine.search(k, ctx(), ExhaustiveSearch())
    engine.close()
    assert [t.config for t in serial.trials] == [t.config
                                                 for t in piped.trials]
    assert all(t.ok() for t in piped.trials)


def test_engine_canonicalize_skips_lowering():
    lowered = {"n": 0}
    sp = ConfigSpace("canon", [Param("k", (1, 2, 3, 4))])

    def make_runner(cfg, c):
        lowered["n"] += 1
        fn = jax.jit(lambda x: x * float(min(cfg["k"], 2)))
        return KernelRunner(fn, jnp.ones((8, 128), jnp.float32))

    k = TunableKernel("canon", sp, make_runner=make_runner,
                      canonicalize=lambda cfg, c: {"k": min(cfg["k"], 2)})
    engine = TuningEngine(WallClockTimer(reps=1, warmup=1))
    res = engine.search(k, ctx(), ExhaustiveSearch())
    engine.close()
    assert len(res.trials) == 4
    assert lowered["n"] == 2             # k=3, k=4 never even traced
    assert sum(t.deduped for t in res.trials) == 2


def test_engine_falls_back_to_serial_for_analytical():
    t = TuningEngine(AnalyticalMeasure(get_chip("tpu_v5e")))
    res = t.search(_kernel(), ctx(), ExhaustiveSearch())
    assert res.best == {"a": 16, "b": 1}
    assert res.evaluations == 20


def test_registry_canonical_rules_match_lowered_programs():
    """Canonical-equal configs must lower to identical programs — validates
    the clamp rules in kernels/ops.py against the real kernels."""
    import hashlib

    from repro.kernels.registry import get_kernel
    from repro.core.search import _cfg_key

    spec = get_kernel("matmul")
    case = spec.cases(scale="host")[0]
    c = case.context(get_chip("tpu_v5e"))
    groups = {}
    for cfg in spec.space.valid_configs(c)[:24]:
        ck = _cfg_key(spec.tunable.canonicalize(cfg, c))
        r = spec.tunable.make_runner(cfg, c)
        h = hashlib.sha256(r.lowered_text().encode()).hexdigest()
        groups.setdefault(ck, set()).add(h)
    assert groups
    for ck, hashes in groups.items():
        assert len(hashes) == 1, f"canonical group {ck} spans {len(hashes)} programs"
