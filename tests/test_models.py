"""Model zoo: per-arch smoke tests (reduced configs, one fwd/train step on
CPU, shape + finiteness asserts) and impl-equivalence tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    ForwardOpts, decode_step, forward, init, loss_fn, prefill,
)
from repro.models import attention as ATT
from repro.models import mamba2 as MAM
from repro.models import moe as MOE
from repro.models.config import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models.param import init_params, param_count
from repro.models.lm import lm_specs


def _batch(cfg, B=2, S=24, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.enc_seq, cfg.d_model))
    if cfg.n_prefix:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (B, cfg.n_prefix, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    """One forward + one gradient step on the reduced config: output shapes
    correct, loss finite, grads finite and nonzero."""
    cfg = get_config(arch, smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    B, S = batch["tokens"].shape

    logits, _ = forward(params, cfg, batch["tokens"],
                        prefix_embeds=batch.get("prefix_embeds"),
                        enc_embeds=batch.get("enc_embeds"))
    exp_s = S + (cfg.n_prefix if cfg.n_prefix else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=1, S=20)
    toks = batch["tokens"]
    fkw = {k: batch[k] for k in ("prefix_embeds", "enc_embeds") if k in batch}
    off = batch["prefix_embeds"].shape[1] if "prefix_embeds" in batch else 0
    logits, _ = forward(params, cfg, toks, **fkw)
    lp, cache = prefill(params, cfg, toks[:, :16], max_len=off + 20, **fkw)
    errs = [float(jnp.max(jnp.abs(lp - logits[:, off + 15])))]
    for t in range(16, 20):
        ld, cache = decode_step(params, cfg, toks[:, t:t + 1], cache,
                                jnp.int32(off + t))
        errs.append(float(jnp.max(jnp.abs(ld - logits[:, off + t]))))
    assert max(errs) < 5e-4, f"{arch}: prefill/decode drift {max(errs)}"


def test_full_configs_match_published_param_counts():
    expected = {
        "phi4-mini-3.8b": 3.8e9, "stablelm-12b": 12.1e9,
        "h2o-danube-3-4b": 4.0e9, "phi3-mini-3.8b": 3.8e9,
        "olmoe-1b-7b": 6.9e9, "deepseek-v2-lite-16b": 15.7e9,
        "whisper-medium": 0.79e9, "internvl2-76b": 70.6e9,
        "mamba2-2.7b": 2.7e9, "jamba-1.5-large-398b": 398e9,
    }
    for arch, want in expected.items():
        got = param_count(lm_specs(get_config(arch)))
        assert abs(got - want) / want < 0.05, (arch, got, want)


def test_scan_plan_covers_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch)
        plan = cfg.scan_plan()
        assert sum(len(u) * r for u, r in plan) == cfg.n_layers
        # round-trip: plan expansion == layer kinds
        flat = [k for u, r in plan for _ in range(r) for k in u]
        assert flat == cfg.layer_kinds()


def test_jamba_pattern_is_1_to_7_with_moe_every_other():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = cfg.layer_kinds()
    assert sum(k.startswith("attn") for k in kinds) == 9       # 72 / 8
    assert sum(k.endswith("_moe") for k in kinds) == 36        # every 2nd


# ---------------------------------------------------------------------------
# impl equivalence
# ---------------------------------------------------------------------------

def _qkv(seed=0, B=2, S=128, Hq=8, Hkv=2, D=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, D)),
            jax.random.normal(ks[1], (B, S, Hkv, D)),
            jax.random.normal(ks[2], (B, S, Hkv, D)))


@pytest.mark.parametrize("window", [None, 48])
def test_attention_impls_agree(window):
    q, k, v = _qkv()
    base = ATT.full_attention(q, k, v, causal=True, window=window)
    for impl in ("chunked", "triangular", "pallas"):
        out = ATT.run_attention(q, k, v, impl=impl, causal=True,
                                window=window, chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5, err_msg=impl)


def test_attention_grads_agree_across_impls():
    q, k, v = _qkv(S=64)
    def loss(impl):
        return jax.grad(lambda q_: ATT.run_attention(
            q_, k, v, impl=impl, causal=True, chunk=32).sum())(q)
    g_full = loss("full")
    for impl in ("chunked", "pallas", "triangular"):
        np.testing.assert_allclose(np.asarray(loss(impl)),
                                   np.asarray(g_full), atol=2e-4,
                                   err_msg=impl)


def test_moe_index_vs_einsum_dispatch():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64,
                      vocab_size=100, dtype="float32",
                      moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                                    n_shared_experts=1, capacity_factor=8.0))
    p = init_params(jax.random.PRNGKey(0), MOE.moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    o1, a1 = MOE.apply_moe(p, x, cfg)
    o2, a2 = MOE.apply_moe_einsum(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 some tokens drop, but the layer stays
    finite and the load-balance loss is well-defined."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64,
                      vocab_size=100, dtype="float32",
                      moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                                    capacity_factor=1.0))
    p = init_params(jax.random.PRNGKey(0), MOE.moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    o, aux = MOE.apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.isfinite(aux))


def test_ssd_chunk_size_semantics_free():
    """SSD chunk length is an autotunable: it must never change results."""
    B, S, H, P, N = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xdt = jax.random.normal(ks[0], (B, S, H, P)) * 0.3
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    B_ = jax.random.normal(ks[2], (B, S, N)) * 0.3
    C_ = jax.random.normal(ks[3], (B, S, N)) * 0.3
    y8, st8 = MAM.ssd_chunked(xdt, dA, B_, C_, 8)
    for chunk in (4, 16, 64):
        y, st = MAM.ssd_chunked(xdt, dA, B_, C_, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y8), atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st8),
                                   atol=1e-4)
