"""Speculative decoding on the paged engine: token equality, acceptance,
rollback accounting, and the serving-edge-case regression sweep.

The design invariant under test everywhere: with greedy accept/rollback,
``speculative=K`` changes throughput only — every request's token stream
is byte-identical to the plain one-token-per-step engine, across kv8
int8 pools, slot reuse, preemption, and tensor parallelism.

Also home to the PR's bugfix regressions:
  * device-table staleness — rollback must never free-and-regrow a
    slot's pages (the page can migrate to another slot under a stale
    device table; ``Scheduler.commit_verify`` keeps the reservation)
  * ``_park`` page-boundary accounting at exact page-multiple positions
  * ``max_tokens`` charging the K-token verify burst up front, and a
    clean preempt when the pool exhausts mid-burst
  * the run loop fast-forwarding virtual time over preemption backoff
    instead of hot-looping one step per backoff tick
"""

import copy

import numpy as np
import pytest

from repro.serving import (
    NgramDrafter, Request, RequestState, Scheduler, ServingEngine,
)
from repro.serving.page_pool import PagePool


# ---------------------------------------------------------------------------
# Drafter unit behavior
# ---------------------------------------------------------------------------

def test_drafter_learns_repetition():
    d = NgramDrafter(min_n=1, max_n=3)
    d.observe([5, 1, 2, 3, 1, 2, 3, 1, 2, 3])
    # Suffix ...1,2,3 -> the most recent continuation of (2,3) is 1.
    assert d.propose(3) == [1, 2, 3]


def test_drafter_fallback_is_fixed_width():
    d = NgramDrafter()
    assert d.propose(4) == [0, 0, 0, 0]       # nothing observed yet
    d.observe([7])
    out = d.propose(4)
    assert len(out) == 4                       # always exactly k drafts
    assert out[0] == 7                         # repeat-last fallback


def test_drafter_observe_is_incremental():
    d = NgramDrafter()
    d.observe([1, 2, 3])
    d.observe([1, 2, 3, 4, 5])                 # append-only extension
    assert d.observed == 5
    with pytest.raises(AssertionError):
        d.observe([1, 2])                      # streams never shrink


def test_drafter_latest_occurrence_wins():
    d = NgramDrafter(min_n=1, max_n=2)
    d.observe([1, 2, 9, 1, 2, 7, 1, 2])
    assert d.propose(1) == [7]                 # latest (1,2) -> 7, not 9


# ---------------------------------------------------------------------------
# Engine token equality: speculation is a pure performance knob
# ---------------------------------------------------------------------------

def _tiny_cfg(vocab=128, n_layers=2):
    from repro.models.config import ModelConfig
    return ModelConfig(name="spec-t", family="dense", n_layers=n_layers,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=vocab, dtype="float32")


def _params(cfg):
    import jax

    from repro.models import lm
    from repro.models.param import init_params
    return init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))


def _reqs(cfg, n=6, gen=12, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(6, 14))
                                        ).astype(np.int32),
                    max_new_tokens=gen, arrival=float(i))
            for i in range(n)]


@pytest.mark.parametrize("quant", [None, "kv8"])
@pytest.mark.parametrize("spec_k", [2, 4])
def test_spec_token_equality(quant, spec_k):
    """Speculative output == plain greedy output, float32 and kv8 pools.
    More requests than slots, so retirement recycles pages into new
    sequences mid-trace — the regression surface of the device-table
    staleness bug (a rolled-back page re-allocated to another slot)."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    kw = dict(num_pages=1 + 4 * 6, page_size=8, max_batch=4,
              max_seq_len=40, prefill_chunk=8, quant=quant)
    plain = ServingEngine(cfg, params, **kw)
    p_reqs = _reqs(cfg, n=6)
    plain.run(p_reqs)
    spec = ServingEngine(cfg, params, **kw, speculative=spec_k)
    s_reqs = _reqs(cfg, n=6)
    res = spec.run(s_reqs)
    assert [r.tokens for r in s_reqs] == [r.tokens for r in p_reqs]
    assert all(len(r.tokens) == r.max_new_tokens for r in s_reqs)
    sp = res["speculative"]
    assert sp["draft_k"] == spec_k and not sp["degraded"]
    # Every decode-phase token goes through verify; each request's first
    # token comes out of the final prefill chunk instead.
    assert sp["committed_tokens"] == res["generated_tokens"] - len(s_reqs)
    spec.scheduler.check_invariants()
    assert spec.pool.num_allocated == 0


def test_spec_acceptance_exceeds_one():
    """On a repetition-prone model (1 layer, small vocab) the n-gram
    drafter lands real drafts: > 1 accepted token per verify step.
    Acceptance is deterministic — greedy model, fixed seeds."""
    cfg = _tiny_cfg(vocab=64, n_layers=1)
    params = _params(cfg)
    engine = ServingEngine(cfg, params, num_pages=1 + 4 * 6, page_size=8,
                           max_batch=4, max_seq_len=48, prefill_chunk=8,
                           speculative=4)
    res = engine.run(_reqs(cfg, n=6, gen=24))
    sp = res["speculative"]
    assert sp["accepted_per_step"] > 1.0, sp
    assert sp["verify_steps"] > 0
    assert res["terminal_requests"] == 6


def test_spec_token_equality_under_preemption():
    """Satellite: pool exhaustion DURING speculative serving — the
    K-token burst makes slots grow pages_for(pos + K), so a tight pool
    preempts mid-burst. The preempt must be clean (no refcount
    corruption, invariants hold) and resumed requests still match the
    uninterrupted plain run token-for-token."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    kw = dict(page_size=4, max_batch=2, max_seq_len=36, prefill_chunk=4)
    big = ServingEngine(cfg, params, num_pages=64, **kw)
    p_reqs = _reqs(cfg, n=4, gen=8, seed=5)
    big.run(p_reqs)
    assert big.scheduler.preemptions == 0

    tight = ServingEngine(cfg, params, num_pages=9, **kw, speculative=4)
    s_reqs = _reqs(cfg, n=4, gen=8, seed=5)
    res = tight.run(s_reqs)
    assert tight.scheduler.preemptions > 0, "pool never exhausted"
    assert tight.scheduler.resumes > 0
    assert [r.tokens for r in s_reqs] == [r.tokens for r in p_reqs]
    assert res["terminal_requests"] == 4
    tight.scheduler.check_invariants()
    assert tight.pool.num_allocated == 0


def test_spec_token_equality_tp2():
    """TP=2 sharded speculative serving (forced host devices) matches
    the single-device plain engine token-for-token: the tp verify step
    runs paged_verify on per-shard local shapes inside shard_map."""
    from conftest import run_in_subprocess
    out = run_in_subprocess("""
import copy, os, tempfile
os.environ["REPRO_TUNING_CACHE"] = tempfile.mkdtemp()
import jax, numpy as np
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.param import init_params
from repro.serving import Request, ServingEngine

cfg = ModelConfig(name="spec-tp", family="dense", n_layers=2, d_model=32,
                  n_heads=8, n_kv_heads=4, head_dim=8, d_ff=64,
                  vocab_size=128, dtype="float32")
params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
def reqs():
    rng = np.random.default_rng(5)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(9, 13))
                                        ).astype(np.int32),
                    max_new_tokens=8, arrival=float(i)) for i in range(4)]
kw = dict(page_size=8, max_batch=2, max_seq_len=40, prefill_chunk=8)
plain = ServingEngine(cfg, params, num_pages=64, **kw)
p = reqs(); plain.run(p)
spec = ServingEngine(cfg, params, num_pages=64, tp=2, **kw, speculative=4)
s = reqs(); res = spec.run(s)
assert [r.tokens for r in s] == [r.tokens for r in p], (s, p)
assert res["speculative"]["committed_tokens"] == res["generated_tokens"] - len(s)
spec.scheduler.check_invariants()
assert spec.pool.num_allocated == 0
print("OK", res["speculative"]["accepted_per_step"])
""", devices=2, timeout=900)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Rollback page accounting
# ---------------------------------------------------------------------------

def test_commit_verify_keeps_burst_reservation():
    """Rollback must NOT free the rejected tail's pages: a slot's page
    list only ever grows while occupied — the engine's device-table
    cache keys on (rid, ready, len(pages)) and a free-then-regrow can
    silently remap the slot onto a page another slot now owns."""
    pool = PagePool(16, 4)
    sched = Scheduler(pool, max_batch=1, max_pages=8, prefill_chunk=4,
                      spec_k=4)
    req = Request(rid=0, prompt=np.arange(1, 8, dtype=np.int32),
                  max_new_tokens=8)
    sched.submit(req)
    sched.admit()
    seq = sched.slots[0]
    seq.pos = 7
    seq.prompt_done = True
    req.tokens = [9]
    assert sched.decode_mask(lookahead=4).all()
    pages_before = list(seq.pages)      # covers pos + 4 = 11 -> 3 pages
    assert len(pages_before) == 3
    req.tokens.extend([1])
    sched.commit_verify(0, 1)           # 1 of 4 drafts accepted
    assert seq.pos == 8
    assert seq.pages == pages_before, "rollback must not shrink pages"
    sched.check_invariants()


def test_max_tokens_charges_verify_burst():
    """Satellite: admission must charge the K-token scatter up front —
    the deepest verify step holds total - 2 + K resident tokens."""
    pool = PagePool(64, 4)
    plain = Scheduler(pool, max_batch=1, max_pages=16)
    spec = Scheduler(pool, max_batch=1, max_pages=16, spec_k=6)
    req = Request(rid=0, prompt=np.ones(9, np.int32), max_new_tokens=8)
    assert plain.max_tokens(req) == 17          # prompt + gen
    assert spec.max_tokens(req) == 9 + 8 - 2 + 6
    # A request that fits plain but whose burst overflows the table
    # width must be rejected at submit, not corrupt the pool mid-burst.
    tiny = Scheduler(PagePool(64, 4), max_batch=1, max_pages=5, spec_k=6)
    big = Request(rid=1, prompt=np.ones(9, np.int32), max_new_tokens=8)
    tiny.submit(big)
    assert big.state is RequestState.FAILED
    assert "table width" in big.failure_reason


# ---------------------------------------------------------------------------
# Satellite: _park page-boundary accounting
# ---------------------------------------------------------------------------

def _boundary_engine(cfg, params, **over):
    kw = dict(num_pages=64, page_size=4, max_batch=1, max_seq_len=32,
              prefill_chunk=4, prefix_cache=True)
    kw.update(over)
    return ServingEngine(cfg, params, **kw)


@pytest.mark.parametrize("gen", [8, 6])
def test_park_boundary_preempt_resume(gen):
    """Preempt exactly at a page-multiple position (gen=8: pos = 9 +
    8 - 1 = 16 = 4 pages) and mid-page (gen=6: pos = 14), resume
    through the prefix trie, and finish — output must equal the
    uninterrupted run either way. At the boundary the parked slice
    must cover exactly pos tokens (the whole resident stream) and the
    growth page holding no valid token must be freed, not parked."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    prompt = np.arange(1, 10, dtype=np.int32)      # prompt_len 9

    plain = _boundary_engine(cfg, params, prefix_cache=False)
    p_req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=gen)
    plain.run([p_req])

    engine = _boundary_engine(cfg, params)
    req = Request(rid=0, prompt=prompt.copy(), max_new_tokens=gen)
    engine._check(req)
    engine.scheduler.submit(req)
    target = 9 + gen - 1 - 1                       # one short of retiring
    ps = engine.pool.page_size
    for _ in range(200):
        engine.step()
        seq = engine.scheduler.slots[0]
        if seq is not None and seq.prompt_done and seq.pos >= target:
            break
    seq = engine.scheduler.slots[0]
    assert seq is not None and seq.pos == target
    if gen == 8:
        assert seq.pos % ps != 0       # mid-run; boundary comes at park
    engine.scheduler.preempt(0)
    engine.scheduler.check_invariants()
    # Parked pages cover exactly the full pages below pos; at an exact
    # boundary that is every resident token.
    parked = engine.prefix_cache.num_pages
    assert parked == (target // ps)
    for _ in range(200):
        engine.step()
        if req.terminal():
            break
    assert req.state is RequestState.FINISHED
    assert req.tokens == p_req.tokens
    # Resume re-prefilled only the post-cache suffix: the trie served
    # the parked prefix (cached tokens strictly positive).
    assert engine.scheduler.total_cached_tokens > 0
    engine.scheduler.retire_finished()
    engine.scheduler.check_invariants()


def test_park_boundary_retire_exact_page_multiple():
    """Retire with pos on an exact page boundary (prompt 9 + gen 8 - 1
    = 16 = 4*4): every resident token parks, the last growth page is
    freed, and a follow-up request with the same prompt hits the trie
    and still matches plain output."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    prompt = np.arange(1, 10, dtype=np.int32)

    plain = _boundary_engine(cfg, params, prefix_cache=False)
    p1 = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    plain.run([p1])

    engine = _boundary_engine(cfg, params)
    r1 = Request(rid=0, prompt=prompt.copy(), max_new_tokens=8)
    engine.run([r1])
    ps = engine.pool.page_size
    assert (9 + 8 - 1) % ps == 0                   # the boundary case
    assert engine.prefix_cache.num_pages == (9 + 8 - 1) // ps
    assert r1.tokens == p1.tokens
    # Second pass: same prompt, served from the parked pages.
    r2 = Request(rid=1, prompt=prompt.copy(), max_new_tokens=8)
    engine.run([r2])
    assert r2.tokens == p1.tokens
    stats = engine.prefix_cache.stats()
    assert stats["hits"] >= 1 and stats["hit_tokens"] > 0
    engine.scheduler.check_invariants()


# ---------------------------------------------------------------------------
# Satellite: backoff fast-forward (engine idle-spin)
# ---------------------------------------------------------------------------

def test_backed_off_queue_drains_in_bounded_steps():
    """A fully-backed-off queue (no active slots, no fault plan) must
    drain by jumping the virtual step clock, not by spinning one step
    per backoff tick — 50k ticks of backoff in a handful of steps."""
    cfg = _tiny_cfg()
    params = _params(cfg)
    engine = ServingEngine(cfg, params, num_pages=16, page_size=8,
                           max_batch=2, max_seq_len=32, prefill_chunk=8)
    req = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=4)
    req.not_before_step = 50_000      # as if deep in preemption backoff
    res = engine.run([req])
    assert req.state is RequestState.FINISHED
    assert res["steps"] < 50, res["steps"]


def test_fast_forward_backoff_scheduler_unit():
    pool = PagePool(16, 4)
    sched = Scheduler(pool, max_batch=1, max_pages=8)
    req = Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=2)
    sched.submit(req)
    req.not_before_step = 1000
    assert sched.backoff_pending()
    assert sched.fast_forward_backoff()
    assert sched._step == 999
    assert sched.admit() == [0]        # eligible on the very next admit
    assert not sched.fast_forward_backoff()   # nothing pending anymore
