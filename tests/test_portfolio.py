"""Config-portfolio tests (core/portfolio.py, "A Few Fit Most").

Four layers, matching the subsystem's moving parts:

  * clustering determinism — the committed shipped_portfolio.json is a
    pure function of the shipped DB bytes (regenerating reproduces it
    byte-for-byte, pinned by a golden fixture),
  * selector units — always a portfolio member, deterministic, layout
    pins (``page_size==pool``) respected, quarantine exclusion honored,
    plus a hypothesis property: ``select`` never yields a config outside
    the kernel's current valid space,
  * Autotuner precedence regressions — portfolio → shipped point entry →
    heuristic → background-tune under ``config_source="portfolio"``,
    point-entry-first with portfolio-on-miss under ``"db"``, and the
    quarantined-winner degrade chain threading through the portfolio,
  * the drift → retune → portfolio-update loop in unit form, and the
    serving acceptance gate: dense == paged == portfolio-sourced,
    token for token.
"""

import copy
import json
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container may lack hypothesis — skip properties
    from conftest import hypothesis_fallback
    given, settings, st = hypothesis_fallback()

from repro.core import (
    AnalyticalMeasure, Autotuner, ConfigSpace, KernelWorkload, Param,
    TunableKernel, TuningCache, TuningContext, get_chip,
)
from repro.core.cache import config_key, make_entry
from repro.core.portfolio import (
    PORTFOLIO_SCHEMA, Portfolio, build_portfolio, config_distance,
    feature_distance, parse_db_key, render_portfolio, scenario_features,
)
from repro.kernels.registry import get_kernel
from repro.obs.drift import DriftDetector

PF_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro",
                       "configs", "shipped_portfolio.json")
DB_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro",
                       "configs", "shipped_tuning_db.json")
GOLDEN = os.path.join(os.path.dirname(__file__), "fixtures", "portfolio",
                      "paged_decode_section.json")


def _load_db():
    with open(DB_PATH) as f:
        return json.load(f)


def _shipped():
    return Portfolio.load(PF_PATH)


# ---------------------------------------------------------------------------
# Clustering determinism: the committed artifact is a pure function of
# the committed DB
# ---------------------------------------------------------------------------

def test_regeneration_is_byte_stable():
    """gen_portfolio on the unchanged shipped DB must reproduce the
    committed artifact exactly — no timestamps, no dict-order luck, no
    float noise. This is the test that keeps the artifact reviewable."""
    with open(PF_PATH) as f:
        committed = f.read()
    data = build_portfolio(_load_db())
    assert render_portfolio(data) == committed, \
        "build_portfolio(shipped DB) drifted from the committed artifact " \
        "— rerun PYTHONPATH=src python -m repro.configs.gen_portfolio"


def test_golden_paged_decode_section():
    """Byte-level golden fixture for one kernel section: catches both
    nondeterminism and silent clustering-behavior changes (a different
    greedy tie-break shows up as a diff here, not just a coverage delta)."""
    with open(GOLDEN) as f:
        golden = f.read()
    data = build_portfolio(_load_db())
    sec = data["kernels"]["paged_decode"]
    assert json.dumps(sec, indent=1, sort_keys=True) + "\n" == golden


def test_build_deterministic_across_calls():
    db = _load_db()
    assert render_portfolio(build_portfolio(db)) == \
        render_portfolio(build_portfolio(db))


def test_artifact_schema_and_size_budget():
    pf = _shipped()
    assert pf.data["schema"] == PORTFOLIO_SCHEMA
    counts = pf.counts()
    db = _load_db()
    assert counts["members"] <= 0.25 * len(db), \
        f"portfolio ({counts['members']} members) defeats its purpose " \
        f"against a {len(db)}-entry DB"
    assert counts["kernels"] >= 5


def test_threshold_tightening_never_shrinks_membership():
    """A tighter coverage threshold needs at least as many members —
    a cheap sanity check on the greedy objective's direction."""
    db = _load_db()
    loose = build_portfolio(db, threshold=0.50, max_members=8)
    tight = build_portfolio(db, threshold=0.02, max_members=8)

    def n_members(d):
        return sum(len(s["members"]) for s in d["kernels"].values())

    assert n_members(tight) >= n_members(loose)


# ---------------------------------------------------------------------------
# Distances: the clustering/selector metrics themselves
# ---------------------------------------------------------------------------

def test_config_distance_bounds_and_identity():
    space = get_kernel("paged_decode").tunable.space
    a = {"page_size": 8, "block_kv": 8, "pack_gqa": True}
    b = {"page_size": 256, "block_kv": 2048, "pack_gqa": False}
    assert config_distance(a, a, space) == 0.0
    d = config_distance(a, b, space)
    assert 0.0 < d <= 1.0
    assert config_distance(a, b, space) == config_distance(b, a, space)


def test_feature_distance_orders_by_pin_and_shape():
    ctx = TuningContext(chip=get_chip("tpu_v5e"),
                        shapes={"q": (16, 32, 128), "k": (16, 8, 32768, 128)})
    same = scenario_features(ctx)
    near = scenario_features(TuningContext(
        chip=get_chip("tpu_v5e"),
        shapes={"q": (16, 32, 128), "k": (16, 8, 16384, 128)}))
    far = scenario_features(TuningContext(
        chip=get_chip("tpu_v5e"), dtype="int8",
        shapes={"q": (16, 32, 128), "k": (16, 8, 32768, 128)},
        extra={"page_size": 8}))
    assert feature_distance(same, same) == 0.0
    assert feature_distance(same, near) < feature_distance(same, far)


# ---------------------------------------------------------------------------
# Selector units against the shipped artifact
# ---------------------------------------------------------------------------

def test_select_covers_every_shipped_scenario():
    """Every current, finite scenario the portfolio was built from must
    get a member back — and always one of the kernel's members, valid for
    that scenario's context (the completeness pass in build_portfolio)."""
    pf = _shipped()
    db = _load_db()
    checked = 0
    for key in sorted(db):
        k, ctx = parse_db_key(key)
        kernel = get_kernel(k["kernel"]).tunable
        if (k["kernel_version"] != kernel.version
                or k["space"] != kernel.space.space_hash()):
            continue
        cfg = pf.select(kernel, ctx)
        assert cfg is not None, \
            f"{kernel.name}: no member for shipped scenario {ctx.signature()}"
        assert kernel.space.why_invalid(cfg, ctx) is None
        members = {config_key(m) for m in pf.members(kernel.name)}
        assert config_key(cfg) in members
        checked += 1
    assert checked > 300


def test_select_is_deterministic_across_instances():
    db = _load_db()
    a, b = _shipped(), _shipped()
    for key in sorted(db)[:40]:
        k, ctx = parse_db_key(key)
        kernel = get_kernel(k["kernel"]).tunable
        assert a.select(kernel, ctx) == b.select(kernel, ctx)
        assert a.select(kernel, ctx) == a.select(kernel, ctx)


def test_select_respects_page_size_pin():
    """The ``page_size==pool`` constraint: a runtime context that pins the
    pool layout must only ever get a matching member (or None — regressed
    beats invalid, but invalid is never served)."""
    pf = _shipped()
    kernel = get_kernel("paged_decode").tunable
    from repro.configs import get_config
    from repro.configs.gen_shipped_db import paged_deployment_shapes
    shapes = paged_deployment_shapes(get_config("phi3-mini-3.8b"))
    served = 0
    for ps in (8, 16, 32, 64, 128, 256):
        ctx = TuningContext(chip=get_chip("tpu_v5e"), shapes=shapes,
                            dtype="bfloat16", extra={"page_size": ps})
        cfg = pf.select(kernel, ctx)
        if cfg is not None:
            assert cfg["page_size"] == ps
            served += 1
    assert served >= 1, "no pin value could be served at all"


def test_select_honors_exclude():
    """Quarantine plumbing: an excluded member is never returned, even
    when it is the selector's first choice."""
    pf = _shipped()
    kernel = get_kernel("rms_norm").tunable
    ctx = TuningContext(chip=get_chip("tpu_v5e"),
                        shapes={"x": (8192, 3072)})
    first = pf.select(kernel, ctx)
    assert first is not None
    second = pf.select(kernel, ctx, exclude=[first])
    assert second is None or config_key(second) != config_key(first)
    # rms_norm shipped several members, so a fallback should exist.
    assert second is not None


def test_stale_section_never_serves():
    pf = _shipped()
    kernel = get_kernel("paged_decode").tunable
    data = copy.deepcopy(pf.data)
    data["kernels"]["paged_decode"]["version"] += 1
    stale = Portfolio(data)
    ctx = TuningContext(chip=get_chip("tpu_v5e"),
                        shapes={"q": (16, 32, 96), "k": (16, 8, 32768, 96)})
    assert stale.select(kernel, ctx) is None
    assert pf.select(kernel, ctx) is not None


def test_bad_schema_rejected():
    with pytest.raises(ValueError):
        Portfolio({"schema": 999, "kernels": {}})


_PAGED = get_kernel("paged_decode").tunable


@given(b=st.integers(1, 64),
       hq=st.sampled_from([2, 4, 8, 16, 32, 96]),
       ratio=st.sampled_from([1, 2, 4, 8]),
       dh=st.sampled_from([64, 96, 128]),
       t=st.integers(8, 65536),
       ps=st.sampled_from([None, 8, 16, 32, 64, 128, 256, 17]),
       dtype=st.sampled_from(["bfloat16", "float32", "int8"]),
       chip=st.sampled_from(["tpu_v4", "tpu_v5e", "tpu_v6e"]))
@settings(max_examples=60, deadline=None)
def test_property_select_never_leaves_valid_space(b, hq, ratio, dh, t, ps,
                                                  dtype, chip):
    """For ANY scenario — including shapes and pins the offline pass never
    saw, and a page_size pin (17) outside the tunable domain — select
    returns None or a member that is valid under the kernel's current
    constraints. The selector may regress; it may never mis-serve."""
    hkv = max(1, hq // ratio)
    extra = {} if ps is None else {"page_size": ps}
    ctx = TuningContext(chip=get_chip(chip),
                        shapes={"q": (b, hq, dh), "k": (b, hkv, t, dh)},
                        dtype=dtype, extra=extra)
    pf = _shipped()
    cfg = pf.select(_PAGED, ctx)
    if cfg is None:
        return
    assert _PAGED.space.why_invalid(cfg, ctx) is None
    members = {config_key(m) for m in pf.members("paged_decode")}
    assert config_key(cfg) in members


# ---------------------------------------------------------------------------
# Autotuner precedence: portfolio → point entry → heuristic → background
# ---------------------------------------------------------------------------

def _space():
    return ConfigSpace("k", [Param("blk", (32, 64, 128, 256, 512))])


def _kernel():
    def wl(cfg, ctx):
        return KernelWorkload(flops=1e9, hbm_bytes=1e8 / cfg["blk"],
                              grid_steps=4096 // cfg["blk"], vmem_bytes=1024)
    return TunableKernel("k", _space(), workload_fn=wl,
                         heuristic=lambda ctx: {"blk": 64})


def _ctx(seq=1024):
    return TuningContext(chip=get_chip("tpu_v5e"), shapes={"x": (seq, 128)})


def _empty_pf():
    return Portfolio({"schema": PORTFOLIO_SCHEMA, "threshold": 0.1,
                      "max_members": 8, "source_entries": 0, "kernels": {}})


def _tuner(tmp_path, *, on_miss="error", portfolio=None,
           config_source="db"):
    return Autotuner(cache=TuningCache(cache_dir=str(tmp_path / "c")),
                     backend=AnalyticalMeasure(get_chip("tpu_v5e")),
                     on_miss=on_miss, portfolio=portfolio,
                     config_source=config_source)


def _seed_point_entry(t, k, c, blk=512):
    t.cache.put(k.name, k.version, k.space, c,
                make_entry({"blk": blk}, 1e-3, 5, "exhaustive",
                           t.backend.name, "tpu_v5e"))


def test_portfolio_first_beats_point_entry(tmp_path):
    """config_source="portfolio": the member serves even when a point
    entry exists — the small-artifact operating mode satellite 4 pins."""
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    assert pf.admit(k, c, {"blk": 128})
    t = _tuner(tmp_path, portfolio=pf, config_source="portfolio")
    _seed_point_entry(t, k, c, blk=512)
    assert t.best_config(k, c) == {"blk": 128}
    st_ = t.stats()
    assert st_["portfolio_serves"] == 1 and st_["hits"] == 0


def test_db_mode_point_entry_beats_portfolio(tmp_path):
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 128})
    t = _tuner(tmp_path, portfolio=pf, config_source="db")
    _seed_point_entry(t, k, c, blk=512)
    assert t.best_config(k, c) == {"blk": 512}
    st_ = t.stats()
    assert st_["hits"] == 1 and st_["portfolio_serves"] == 0


def test_db_mode_miss_serves_portfolio_before_heuristic(tmp_path):
    """On a point miss the portfolio member beats the heuristic default —
    and the scenario is still enqueued so the cache converges off the
    critical path. on_miss="error" proves the portfolio intercepted the
    miss: without it this call raises."""
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 128})
    t = _tuner(tmp_path, on_miss="error", portfolio=pf, config_source="db")
    assert t.best_config(k, c) == {"blk": 128}
    assert len(t.queue) == 1
    t.attach_portfolio(None)
    with pytest.raises(LookupError):
        t.best_config(k, _ctx(seq=2048))


def test_portfolio_mode_falls_back_to_point_entry(tmp_path):
    """An empty (or non-serving) portfolio under config_source="portfolio"
    degrades to the point DB, not to an error."""
    k, c = _kernel(), _ctx()
    t = _tuner(tmp_path, portfolio=_empty_pf(), config_source="portfolio")
    _seed_point_entry(t, k, c, blk=512)
    assert t.best_config(k, c) == {"blk": 512}
    assert t.stats()["hits"] == 1


def test_config_source_tune_ignores_portfolio(tmp_path):
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 128})
    t = _tuner(tmp_path, on_miss="heuristic", portfolio=pf,
               config_source="tune")
    assert t.best_config(k, c) == {"blk": 64}      # the heuristic
    assert t.stats()["portfolio_serves"] == 0
    assert {"blk": 128} not in t.fallback_configs(k, c)


def test_db_mode_converges_to_point_winner_after_flush(tmp_path):
    """Miss → portfolio serve + enqueue → background tune → point entry
    wins thereafter, and the fresh winner is admitted into the live
    portfolio (the online half)."""
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 128})
    t = _tuner(tmp_path, on_miss="heuristic", portfolio=pf,
               config_source="db")
    assert t.best_config(k, c) == {"blk": 128}
    assert t.flush_tuning_queue() == 1
    assert t.best_config(k, c) == {"blk": 512}     # tuned optimum, cache hit
    st_ = t.stats()
    assert st_["hits"] == 1 and st_["portfolio_updates"] >= 1
    assert pf.select(k, c) == {"blk": 512}         # portfolio tracked it


def test_quarantined_winner_degrades_through_portfolio(tmp_path):
    """The PR-7 degrade chain with a portfolio attached: quarantined
    winner → runners-up → (all quarantined) → portfolio member — before
    the heuristic default ever enters."""
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 32})
    t = _tuner(tmp_path, on_miss="heuristic", portfolio=pf,
               config_source="db")
    t.tune(k, c)
    entry = t.cache.get_raw(k.name, k.version, k.space, c)
    assert entry.config == {"blk": 512}
    ru = [dict(r["config"]) for r in entry.runners_up]
    assert ru, "tune produced no runners-up"
    # Quarantine the winner: best_config degrades to the first runner-up.
    t.quarantine(k, c, {"blk": 512})
    assert t.best_config(k, c) == ru[0]
    assert t.stats()["fallback_serves"] == 1
    # Quarantine every runner-up too: the portfolio member is next.
    for cfg in ru:
        t.quarantine(k, c, cfg)
    assert t.best_config(k, c) == {"blk": 32}
    assert t.stats()["portfolio_serves"] == 1
    # And the member never resurfaces once quarantined itself.
    t.quarantine(k, c, {"blk": 32})
    assert t.best_config(k, c) == {"blk": 64}      # heuristic, last resort
    assert {"blk": 32} not in t.fallback_configs(k, c)


def test_fallback_chain_orders_runners_then_portfolio_then_default(tmp_path):
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    pf.admit(k, c, {"blk": 32})
    t = _tuner(tmp_path, on_miss="heuristic", portfolio=pf,
               config_source="db")
    t.tune(k, c)
    entry = t.cache.get_raw(k.name, k.version, k.space, c)
    ru = [dict(r["config"]) for r in entry.runners_up]
    chain = t.fallback_configs(k, c, exclude=[entry.config])
    assert chain[:len(ru)] == ru
    assert chain[len(ru)] == {"blk": 32}           # portfolio member
    # The heuristic default ({"blk": 64}) closes the chain — here it is
    # already a runner-up, so dedup leaves the member as the tail.
    assert {"blk": 64} in chain


def test_admit_refuses_invalid_and_resets_stale(tmp_path):
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    assert not pf.admit(k, c, {"blk": 12345})      # off-domain: refused
    assert pf.admit(k, c, {"blk": 128})
    assert pf.select(k, c) == {"blk": 128}
    # A version bump makes the section stale: the next admit resets it
    # instead of mixing members across incompatible spaces.
    k2 = _kernel()
    k2.version = k.version + 1
    assert pf.select(k2, c) is None
    assert pf.admit(k2, c, {"blk": 256})
    assert pf.members("k") == [{"blk": 256}]
    assert pf.select(k2, c) == {"blk": 256}


# ---------------------------------------------------------------------------
# Drift → retune → portfolio update (unit loop)
# ---------------------------------------------------------------------------

def test_drift_retune_updates_portfolio(tmp_path):
    """The full online loop in unit form: a dispatch key drifts past the
    threshold, the detector callback re-enqueues the scenario through
    ``retune_key``, the (flushed) background tune admits the fresh winner
    into the live portfolio, and the selector serves it."""
    k, c = _kernel(), _ctx()
    pf = _empty_pf()
    t = _tuner(tmp_path, on_miss="heuristic", portfolio=pf,
               config_source="db")
    det = DriftDetector(threshold=1.5, alpha=1.0, calibration=2)
    t.enable_drift_retune(det)
    key, shipped = t.dispatch_key(k, c)
    assert shipped is None                         # nothing tuned yet
    assert t.lookup_key(key) is not None
    det.observe(key, 1e-3, kernel=k.name)          # calibration
    det.observe(key, 1e-3, kernel=k.name)
    assert not det.flagged()
    assert det.observe(key, 1e-2, kernel=k.name)   # 10x: flagged
    assert det.flagged() == [key]
    assert t.stats()["drift_retunes"] == 1
    assert len(t.queue) == 1
    assert t.flush_tuning_queue() == 1             # the background daemon
    assert t.stats()["portfolio_updates"] == 1
    assert pf.select(k, c) == {"blk": 512}         # fresh winner is live
    assert t.best_config(k, c) == {"blk": 512}
    # Post-retune the detector key resets so the new config calibrates
    # its own baseline (the serving engine calls this after re-jitting).
    assert det.reset_key(key)
    assert not det.flagged()
    assert not det.reset_key(key)                  # idempotent


def test_retune_key_unknown_is_refused(tmp_path):
    t = _tuner(tmp_path, on_miss="heuristic")
    assert not t.retune_key("no-such-key")
    assert t.stats()["drift_retunes"] == 0


# ---------------------------------------------------------------------------
# Serving acceptance: dense == paged == portfolio-sourced, token for token
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="pf-t", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=128, dtype="float32")


def _reqs(seed, vocab, n=4):
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, vocab, int(p)).astype(np.int32),
                    max_new_tokens=int(g))
            for i, (p, g) in enumerate(zip(rng.integers(2, 10, n),
                                           rng.integers(2, 5, n)))]


def _dense_greedy(params, cfg, prompt, gen):
    import jax.numpy as jnp

    from repro.models import lm
    toks = jnp.asarray(prompt[None], jnp.int32)
    P = len(prompt)
    lg, cache = lm.prefill(params, cfg, toks, max_len=P + gen,
                           opts=lm.ForwardOpts(attn_impl="full"))
    out = [int(jnp.argmax(lg[0]))]
    for i in range(gen - 1):
        lg, cache = lm.decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), cache,
            jnp.int32(P + i), opts=lm.ForwardOpts(decode_impl="full"))
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_portfolio_serving_token_identical(tmp_path):
    """Acceptance gate: the same trace served three ways — dense
    reference, paged with point/heuristic configs, paged with
    portfolio-sourced configs (a genuinely different member config) —
    generates IDENTICAL tokens. Config selection is a performance input,
    never a numerics input."""
    import jax

    from repro.core import tuner as tuner_mod
    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import ServingEngine

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), lm.lm_specs(cfg))
    kw = dict(num_pages=24, page_size=8, max_batch=3, max_seq_len=24,
              prefill_chunk=4)

    t = Autotuner(cache=TuningCache(cache_dir=str(tmp_path / "dt")),
                  on_miss="heuristic", portfolio=_empty_pf(),
                  config_source="db")
    tuner_mod.set_default_tuner(t)
    try:
        # Pass 1 (db mode, empty portfolio): heuristic/point configs.
        eng = ServingEngine(cfg, params, **kw)
        eng.run(_reqs(7, cfg.vocab_size))
        want = {r.rid: list(r.tokens) for r in eng.scheduler.finished}

        # Admit a member for the runtime paged_decode scenario that is
        # NOT the config pass 1 dispatched, then serve portfolio-first.
        item = t.last_dispatch("paged_decode")
        assert item is not None
        ctx, used = item
        kernel = get_kernel("paged_decode").tunable
        alt = next(c for c in kernel.space.valid_configs(ctx)
                   if config_key(c) != config_key(used))
        assert t.portfolio.admit(kernel, ctx, alt)
        t.attach_portfolio(t.portfolio, source="portfolio")

        eng2 = ServingEngine(cfg, params, **kw)
        eng2.run(_reqs(7, cfg.vocab_size))
        got = {r.rid: list(r.tokens) for r in eng2.scheduler.finished}
        assert t.stats()["portfolio_serves"] >= 1, \
            "portfolio-first serving never consulted the portfolio"
        assert got == want, "portfolio-sourced configs changed tokens"
    finally:
        tuner_mod.set_default_tuner(None)

    for rid, toks in sorted(want.items()):
        r = next(r for r in eng.scheduler.finished if r.rid == rid)
        dense = _dense_greedy(params, cfg, r.prompt, r.max_new_tokens)
        assert toks == dense, f"req {rid}: paged {toks} != dense {dense}"
