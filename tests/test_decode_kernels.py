"""The new decode kernel family vs its ref.py oracles: ragged GQA decode
(GQA ratios × ragged KV lengths × layout configs) and absorbed-MLA decode,
plus the model-level pallas dispatch paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gqa_decode import gqa_decode
from repro.kernels.mla_decode import mla_decode


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32).astype(dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


def ragged_lens(seed, B, T):
    return jax.random.randint(jax.random.PRNGKey(seed), (B,), 1, T + 1)


# ---------------------------------------------------------------------------
# ragged GQA decode
# ---------------------------------------------------------------------------

GQA_CASES = [
    # B, Hq, Hkv, T, D, block_kv, k_splits, pack_gqa
    (2, 4, 4, 512, 64, 128, 2, True),        # MHA (group 1)
    (2, 8, 4, 512, 64, 128, 1, True),        # GQA 2:1
    (1, 8, 2, 300, 128, 128, 4, True),       # GQA 4:1, ragged T
    (3, 12, 2, 1024, 128, 256, 1, True),     # GQA 6:1
    (1, 16, 2, 2048, 64, 512, 8, True),      # deep GQA, many splits
    (2, 8, 2, 512, 64, 128, 2, False),       # unpacked: row per q head
    (1, 16, 4, 640, 128, 256, 1, False),     # unpacked GQA 4:1
]


@pytest.mark.parametrize("case", GQA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_vs_ref(case, dtype):
    B, Hq, Hkv, T, D, bk, ks, pack = case
    q = rand(0, (B, Hq, D), dtype)
    k = rand(1, (B, Hkv, T, D), dtype)
    v = rand(2, (B, Hkv, T, D), dtype)
    lens = ragged_lens(3, B, T)
    o = gqa_decode(q, k, v, kv_len=lens, block_kv=bk, k_splits=ks,
                   pack_gqa=pack)
    oref = ref.gqa_decode(q, k, v, kv_len=lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol(dtype))


def test_gqa_decode_config_semantics_free():
    """Layout tunables (block, splits, packing) never change the result."""
    q = rand(0, (2, 8, 64))
    k = rand(1, (2, 2, 512, 64))
    v = rand(2, (2, 2, 512, 64))
    lens = jnp.array([313, 512], jnp.int32)
    base = gqa_decode(q, k, v, kv_len=lens, block_kv=128, k_splits=1,
                      pack_gqa=True)
    for bk, ks, pack in [(128, 4, True), (256, 2, True), (512, 1, True),
                         (128, 1, False), (256, 2, False)]:
        o = gqa_decode(q, k, v, kv_len=lens, block_kv=bk, k_splits=ks,
                       pack_gqa=pack)
        np.testing.assert_allclose(np.asarray(o), np.asarray(base),
                                   atol=1e-5)


def test_gqa_decode_ragged_tail_masked():
    """Garbage keys/values beyond each request's kv_len must not leak."""
    B, Hq, Hkv, T, D = 2, 8, 2, 256, 64
    q = rand(0, (B, Hq, D))
    k = rand(1, (B, Hkv, T, D))
    v = rand(2, (B, Hkv, T, D))
    lens = jnp.array([100, 17], jnp.int32)
    o1 = gqa_decode(q, k, v, kv_len=lens, block_kv=128, k_splits=2)
    k2 = k.at[:, :, 120:].set(99.0)
    v2 = v.at[:, :, 120:].set(-99.0)
    o2 = gqa_decode(q, k2, v2, kv_len=lens, block_kv=128, k_splits=2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_decode_kernels_clamp_kv_len_past_cache():
    """kv_len > T means 'attend the whole cache' — zero-padded rows past T
    must never score (the einsum ring-wrap semantics)."""
    B, Hq, Hkv, T, D = 2, 8, 2, 300, 64
    q = rand(0, (B, Hq, D))
    k = rand(1, (B, Hkv, T, D))
    v = rand(2, (B, Hkv, T, D))
    over = jnp.array([310, 350], jnp.int32)
    want = ref.gqa_decode(q, k, v, kv_len=jnp.minimum(over, T))
    got = gqa_decode(q, k, v, kv_len=over, block_kv=512, k_splits=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    qa, qr = rand(3, (B, 4, 128)), rand(4, (B, 4, 64))
    ckv, kr = rand(5, (B, T, 128)), rand(6, (B, T, 64))
    want = ref.mla_decode(qa, qr, ckv, kr, kv_len=jnp.minimum(over, T),
                          scale=0.08)
    got = mla_decode(qa, qr, ckv, kr, kv_len=over, scale=0.08, block_kv=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gqa_decode_entry_point_with_config():
    from repro.kernels import ops
    q = rand(0, (2, 8, 64))
    k = rand(1, (2, 2, 256, 64))
    v = rand(2, (2, 2, 256, 64))
    lens = jnp.array([200, 64], jnp.int32)
    o = ops.ragged_decode(q, k, v, kv_len=lens,
                       config={"block_kv": 128, "k_splits": 2,
                               "pack_gqa": False})
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref.gqa_decode(q, k, v, kv_len=lens)),
        atol=1e-5)


# ---------------------------------------------------------------------------
# MLA decode
# ---------------------------------------------------------------------------

MLA_CASES = [
    # B, H, C, R, T, block_kv, k_splits
    (2, 4, 128, 64, 512, 128, 2),
    (1, 8, 256, 64, 300, 128, 1),            # ragged T
    (2, 16, 512, 64, 1024, 256, 4),          # deepseek-like widths
    (1, 2, 64, 32, 256, 128, 1),             # tiny ranks
]


@pytest.mark.parametrize("case", MLA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_decode_vs_ref(case, dtype):
    B, H, C, R, T, bk, ks = case
    qa = rand(0, (B, H, C), dtype)
    qr = rand(1, (B, H, R), dtype)
    ckv = rand(2, (B, T, C), dtype)
    kr = rand(3, (B, T, R), dtype)
    lens = ragged_lens(4, B, T)
    scale = (C + R) ** -0.5
    o = mla_decode(qa, qr, ckv, kr, kv_len=lens, scale=scale, block_kv=bk,
                   k_splits=ks)
    oref = ref.mla_decode(qa, qr, ckv, kr, kv_len=lens, scale=scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref, np.float32),
                               atol=tol(dtype) * 10)


def test_mla_decode_config_semantics_free():
    qa, qr = rand(0, (2, 4, 128)), rand(1, (2, 4, 64))
    ckv, kr = rand(2, (2, 512, 128)), rand(3, (2, 512, 64))
    lens = jnp.array([401, 37], jnp.int32)
    base = mla_decode(qa, qr, ckv, kr, kv_len=lens, scale=0.08,
                      block_kv=128, k_splits=1)
    for bk, ks in [(128, 4), (256, 2), (512, 1)]:
        o = mla_decode(qa, qr, ckv, kr, kv_len=lens, scale=0.08,
                       block_kv=bk, k_splits=ks)
        np.testing.assert_allclose(np.asarray(o), np.asarray(base),
                                   atol=1e-5)


def test_mla_decode_ragged_tail_masked():
    qa, qr = rand(0, (2, 4, 64)), rand(1, (2, 4, 32))
    ckv, kr = rand(2, (2, 256, 64)), rand(3, (2, 256, 32))
    lens = jnp.array([90, 10], jnp.int32)
    o1 = mla_decode(qa, qr, ckv, kr, kv_len=lens, scale=0.1, block_kv=128)
    ckv2 = ckv.at[:, 100:].set(55.0)
    kr2 = kr.at[:, 100:].set(-55.0)
    o2 = mla_decode(qa, qr, ckv2, kr2, kv_len=lens, scale=0.1, block_kv=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# ---------------------------------------------------------------------------
# model-level pallas dispatch (registry kernels on the decode hot path)
# ---------------------------------------------------------------------------

def _gqa_model_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=128, dtype="float32")


def _mla_model_cfg():
    from repro.models.config import ModelConfig, MLAConfig
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                       vocab_size=128, dtype="float32",
                       mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16,
                                     qk_rope_dim=8, v_head_dim=16))


@pytest.mark.parametrize("make_cfg", [_gqa_model_cfg, _mla_model_cfg])
def test_attn_decode_pallas_matches_full(make_cfg):
    from repro.models import attention as ATT
    from repro.models.param import init_params
    cfg = make_cfg()
    p = init_params(jax.random.PRNGKey(0), ATT.attn_specs(cfg))
    B, S = 2, 8
    xp = rand(1, (B, S, cfg.d_model))
    x = rand(2, (B, 1, cfg.d_model))
    _, cache = ATT.attn_prefill(p, xp, cfg, max_len=S + 4)
    o_full, c_full = ATT.attn_decode(p, x, cfg, cache, jnp.int32(S),
                                     impl="full")
    o_pal, c_pal = ATT.attn_decode(p, x, cfg, cache, jnp.int32(S),
                                   impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_full),
                               atol=2e-5)
    for key in c_full:
        np.testing.assert_allclose(np.asarray(c_pal[key]),
                                   np.asarray(c_full[key]), atol=1e-6)
